# Model configuration shared by every L2 module and mirrored by the Rust
# `config` crate module.  Presets correspond to paper Table 2, scaled to
# this testbed (see DESIGN.md "Hardware-Adaptation").

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 2048
    d_model: int = 128
    n_heads: int = 2
    d_head: int = 64           # per-head dim (Dk == Dv == d_head)
    n_layers: int = 2
    layout: str = "LL"         # 'L' = Linear-MoE block, 'N' = attention-MoE
    lsm: str = "gla"           # LSM instance for 'L' layers
    chunk: int = 64            # LSM / attention kernel chunk size
    n_experts: int = 4
    top_k: int = 2
    d_ffn: int = 128           # per-expert FFN hidden dim
    capacity_factor: float = 2.0
    aux_loss_coef: float = 0.01
    rms_eps: float = 1e-5
    rope_theta: float = 10000.0

    def __post_init__(self):
        assert len(self.layout) == self.n_layers, (
            f"layout {self.layout!r} length != n_layers {self.n_layers}")
        assert set(self.layout) <= {"L", "N"}
        assert self.top_k <= self.n_experts

    @property
    def d_qkv(self):
        return self.n_heads * self.d_head

    def with_(self, **kw):
        d = asdict(self)
        d.update(kw)
        return ModelConfig(**d)


def layout(n_layers: int, hybrid: bool) -> str:
    """Paper §3.3: hybrid = one quarter attention layers, pattern LLLN."""
    if not hybrid:
        return "L" * n_layers
    s = "".join("N" if (i % 4 == 3) else "L" for i in range(n_layers))
    return s


# Presets.  `tiny` gates the test suite + default artifacts; `small` is the
# end-to-end loss-curve scale (paper A0.3B-2B analogue at 1-CPU scale);
# `a0p3b`/`a1b` are shape-faithful paper configs used by the analytical
# memory model only (never compiled on this testbed).
PRESETS = {
    "tiny": ModelConfig(),
    "tiny-hybrid": ModelConfig(n_layers=4, layout=layout(4, True)),
    "small": ModelConfig(
        vocab=4096, d_model=256, n_heads=4, d_head=64, n_layers=4,
        layout="LLLL", n_experts=8, top_k=2, d_ffn=256),
    "small-hybrid": ModelConfig(
        vocab=4096, d_model=256, n_heads=4, d_head=64, n_layers=4,
        layout=layout(4, True), n_experts=8, top_k=2, d_ffn=256),
    # Paper Table 2 (for memcost only).
    "a0p3b": ModelConfig(
        vocab=151936, d_model=1024, n_heads=8, d_head=128, n_layers=12,
        layout="L" * 12, n_experts=64, top_k=8, d_ffn=896),
    "a1b": ModelConfig(
        vocab=151936, d_model=2048, n_heads=16, d_head=128, n_layers=16,
        layout="L" * 16, n_experts=64, top_k=8, d_ffn=1024),
}
