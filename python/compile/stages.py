# L2: artifact-boundary functions for the distributed runtime.
#
# The Rust coordinator composes these small HLO modules into the paper's
# parallelism schemes:
#   - pipeline parallelism (PP): embed / block / head, fwd + recompute-bwd
#     per stage (Megatron-style activation recomputation: the bwd artifact
#     re-runs the forward inside, so only activations cross stages).
#   - LASP sequence parallelism (paper App. A.3): sp_state_* computes the
#     per-rank memory-state contribution (Alg. 1/2 line 6, the thing that
#     is AllGather-ed); sp_output_* combines intra-chunk output with the
#     gathered prefix state (lines 8-11).
#   - hybrid-model SP (paper §2.2.2): attn_sp computes local attention
#     output from the all-gathered K/V (the Llama3-style strategy).
#   - expert parallelism (EP): router / expert pieces the Rust token
#     dispatcher schedules around its all-to-all.

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import lsm as lsm_mod
from . import model as model_mod
from . import moe as moe_mod
from .kernels import chunked
from .lsm import rms_norm


# --------------------------- pipeline stages -------------------------------


def embed_fwd(embed, tokens):
    return embed[tokens]


def embed_bwd(tokens, gx, vocab):
    """Scatter-add token grads into the embedding table."""
    g = jnp.zeros((vocab, gx.shape[-1]), gx.dtype)
    return g.at[tokens.reshape(-1)].add(gx.reshape(-1, gx.shape[-1]))


def block_fwd(cfg: ModelConfig, ch, lp, x):
    """One block forward; returns (y, aux)."""
    return model_mod.block_apply(cfg, ch, lp, x)


def block_bwd(cfg: ModelConfig, ch, lp, x, gy):
    """Recompute-backward for one block: re-runs the forward, then VJP.
    Total loss = ce + coef * sum(aux), so the aux cotangent is coef.
    Returns (gparams, gx)."""
    def f(lp_, x_):
        y, aux = model_mod.block_apply(cfg, ch, lp_, x_)
        return y, aux

    _, vjp = jax.vjp(f, lp, x)
    gparams, gx = vjp((gy, jnp.float32(cfg.aux_loss_coef)))
    return gparams, gx


def head_fwd(cfg: ModelConfig, final_norm, embed, x, targets):
    """Final norm + tied LM head + CE.  Returns (ce,)."""
    h = rms_norm(x, final_norm, cfg.rms_eps)
    logits = h @ embed.T
    mask = (targets >= 0).astype(jnp.float32)
    tsafe = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tsafe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def head_bwd(cfg: ModelConfig, final_norm, embed, x, targets):
    """Returns (g_final_norm, g_embed, gx, ce)."""
    ce, vjp = jax.vjp(
        lambda fn, e, xx: head_fwd(cfg, fn, e, xx, targets),
        final_norm, embed, x)
    gfn, gemb, gx = vjp(jnp.float32(1.0))
    return gfn, gemb, gx, ce


# ------------------------ LASP SP primitives --------------------------------
# Kernel-level (paper Alg. 1/2 operate on Q/K/V chunks directly).


def sp_state(kind, k, v, gates):
    return chunked.sp_chunk_state(kind, k, v, gates)


def sp_output(kind, q, k, v, gates, m_prefix):
    return chunked.sp_chunk_output(kind, q, k, v, gates, m_prefix)


def attn_sp(q_local, k_full, v_full, pos0, scale=None):
    """Hybrid-SP attention: local Q chunk against all-gathered K/V
    (paper §2.2.2 'On Standard Attention Module').  pos0: this rank's
    global offset (scalar int32) for the causal mask."""
    b, h, c, dk = q_local.shape
    n = k_full.shape[2]
    if scale is None:
        scale = dk ** -0.5
    s = jnp.einsum("bhcd,bhnd->bhcn", q_local, k_full) * scale
    qi = pos0 + jnp.arange(c, dtype=jnp.int32)[:, None]
    kj = jnp.arange(n, dtype=jnp.int32)[None, :]
    s = jnp.where(qi >= kj, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhcn,bhnv->bhcv", p, v_full)


# ------------------------------ MoE EP pieces -------------------------------


def moe_router(cfg: ModelConfig, router_w, x):
    return moe_mod.router_fn(cfg, router_w, x)


def moe_expert(w1, w3, w2, x):
    """One expert over a fixed-size group of tokens (tile or capacity)."""
    return moe_mod.expert_tile_fn(w1, w3, w2, x)


def moe_grouped(w1, w3, w2, buf):
    """All local experts over capacity-grouped tokens: one batched einsum.
    w*: (E, ...), buf: (E, cap, d)."""
    return (jax.nn.silu(buf @ w1) * (buf @ w3)) @ w2


# ------------------------------ eval ----------------------------------------


def eval_loss(cfg: ModelConfig, params, tokens, targets):
    """Forward-only loss for held-out perplexity (Tables 5/6 substitution)."""
    loss, ce = model_mod.loss_fn(cfg, params, tokens, targets)
    return loss, ce
