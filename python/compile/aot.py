# AOT exporter: lowers every L2 function to HLO *text* + manifest.json.
#
# HLO text (NOT .serialize()) is the interchange format: jax >= 0.5 emits
# HloModuleProtos with 64-bit instruction ids which the xla crate's
# xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
# reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.
#
# Run via `make artifacts`:  python -m compile.aot --out-dir ../artifacts
# Python runs ONCE here; the Rust runtime (rust/src/runtime) loads the
# artifacts and never calls back into Python.

import argparse
import hashlib
import json
import math
import os
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import PRESETS, ModelConfig, layout as mk_layout
from . import model as model_mod
from . import moe as moe_mod
from . import stages

F32 = jnp.float32
I32 = jnp.int32


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def leaf_specs(tree):
    leaves, _ = jax.tree_util.tree_flatten(tree)
    out = []
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for (path, leaf) in paths:
        out.append({
            "path": jax.tree_util.keystr(path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return out


# ----------------------------- variants ------------------------------------

INSTANCES = ("bla", "retention", "gla", "deltanet", "mamba2", "hgrn2",
             "rwkv6")


def variant_cfg(preset: str, inst: str, arch: str) -> ModelConfig:
    """arch: pure | hybrid | attn."""
    base = PRESETS[preset]
    if arch == "attn":
        return base.with_(layout="N" * base.n_layers)
    if arch == "hybrid":
        return base.with_(lsm=inst, layout=mk_layout(base.n_layers, True))
    return base.with_(lsm=inst, layout="L" * base.n_layers)


def variant_tag(preset, inst, arch):
    if arch == "attn":
        return f"{preset}_attn"
    suffix = "h" if arch == "hybrid" else ""
    return f"{preset}_{inst}{suffix}"


def params_spec(cfg):
    return jax.eval_shape(partial(model_mod.init_params, cfg), 0)


# --------------------------- export registry --------------------------------


class Exporter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.entries = []
        self.variants = {}
        os.makedirs(out_dir, exist_ok=True)

    def add_variant(self, preset, inst, arch):
        tag = variant_tag(preset, inst, arch)
        if tag in self.variants:
            return tag
        cfg = variant_cfg(preset, inst, arch)
        total, act = model_mod.param_count(cfg)
        self.variants[tag] = {
            "preset": preset, "instance": inst, "arch": arch,
            "config": {
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_heads": cfg.n_heads, "d_head": cfg.d_head,
                "n_layers": cfg.n_layers, "layout": cfg.layout,
                "lsm": cfg.lsm, "chunk": cfg.chunk,
                "n_experts": cfg.n_experts, "top_k": cfg.top_k,
                "d_ffn": cfg.d_ffn,
                "capacity_factor": cfg.capacity_factor,
            },
            "params_total": int(total), "params_activated": int(act),
            "param_specs": leaf_specs(params_spec(cfg)),
        }
        return tag

    def export(self, name, fn, args, kind, **meta):
        """Lower fn(*args) and write <name>.hlo.txt."""
        t0 = time.time()
        # keep_unused: jit would otherwise DCE-drop unused parameters from
        # the HLO signature (e.g. xprev/pos in non-RWKV decode steps) and
        # the Rust runtime's positional calling convention would break.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        res_spec = jax.eval_shape(fn, *args)
        self.entries.append({
            "name": name, "file": fname, "kind": kind,
            "args": leaf_specs(args), "results": leaf_specs(res_spec),
            "meta": meta,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        dt = time.time() - t0
        print(f"  [{dt:5.1f}s] {name}  ({len(text)//1024} KiB)")

    def write_manifest(self):
        manifest = {
            "version": 1,
            "variants": self.variants,
            "artifacts": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"wrote {path}: {len(self.entries)} artifacts, "
              f"{len(self.variants)} variants")


# ------------------------------ export sets ---------------------------------


def exp_model(ex: Exporter, preset, inst, arch, batch, seq,
              kinds=("train_step",)):
    tag = ex.add_variant(preset, inst, arch)
    cfg = variant_cfg(preset, inst, arch)
    pspec = params_spec(cfg)
    toks = sds((batch, seq), I32)

    if "init" in kinds:
        ex.export(f"init_{tag}", lambda s: model_mod.init_params(cfg, s),
                  (sds((), I32),), "init", variant=tag)
    if "train_step" in kinds:
        ex.export(
            f"train_step_{tag}_b{batch}n{seq}",
            lambda p, m, v, st, lr, t, g: model_mod.train_step(
                cfg, p, m, v, st, lr, t, g),
            (pspec, pspec, pspec, sds((), I32), sds((), F32), toks, toks),
            "train_step", variant=tag, batch=batch, seq=seq)
    if "fwd_bwd" in kinds:
        ex.export(
            f"fwd_bwd_{tag}_b{batch}n{seq}",
            lambda p, t, g: model_mod.fwd_bwd(cfg, p, t, g),
            (pspec, toks, toks),
            "fwd_bwd", variant=tag, batch=batch, seq=seq)
    if "eval_loss" in kinds:
        ex.export(
            f"eval_loss_{tag}_b{batch}n{seq}",
            lambda p, t, g: stages.eval_loss(cfg, p, t, g),
            (pspec, toks, toks),
            "eval_loss", variant=tag, batch=batch, seq=seq)


def exp_decode(ex: Exporter, preset, inst, arch, batch, max_n=None):
    tag = ex.add_variant(preset, inst, arch)
    cfg = variant_cfg(preset, inst, arch)
    pspec = params_spec(cfg)
    st = jax.eval_shape(
        partial(model_mod.init_decode_state, cfg, batch, max_n), )
    name = f"decode_{tag}_b{batch}" + (f"_n{max_n}" if max_n else "")
    ex.export(
        name,
        lambda p, s, t, pos: model_mod.decode_step(cfg, p, s, t, pos),
        (pspec, st, sds((batch,), I32), sds((), I32)),
        "decode", variant=tag, batch=batch, max_n=max_n or 0)


def exp_pipeline(ex: Exporter, preset, inst, mb, seq):
    """Per-layer pipeline pieces (compose to any depth/PP size in Rust)."""
    for arch, ch in (("pure", "L"), ("attn", "N")):
        tag = ex.add_variant(preset, inst, arch)
        cfg = variant_cfg(preset, inst, arch)
        lp_spec = params_spec(cfg)["layers"][0]
        x = sds((mb, seq, cfg.d_model))
        ex.export(f"block_{ch}_{tag}_mb{mb}n{seq}",
                  lambda lp, xx: stages.block_fwd(cfg, ch, lp, xx),
                  (lp_spec, x), "block_fwd", variant=tag, ch=ch,
                  batch=mb, seq=seq)
        ex.export(f"block_{ch}_bwd_{tag}_mb{mb}n{seq}",
                  lambda lp, xx, gy: stages.block_bwd(cfg, ch, lp, xx, gy),
                  (lp_spec, x, x), "block_bwd", variant=tag, ch=ch,
                  batch=mb, seq=seq)
    # embed / head are arch-independent (use the pure variant's cfg)
    cfg = variant_cfg(preset, inst, "pure")
    tag = variant_tag(preset, inst, "pure")
    emb = sds((cfg.vocab, cfg.d_model))
    toks = sds((mb, seq), I32)
    x = sds((mb, seq, cfg.d_model))
    ex.export(f"embed_{tag}_mb{mb}n{seq}",
              lambda e, t: stages.embed_fwd(e, t), (emb, toks),
              "embed_fwd", variant=tag, batch=mb, seq=seq)
    ex.export(f"embed_bwd_{tag}_mb{mb}n{seq}",
              lambda t, gx: stages.embed_bwd(t, gx, cfg.vocab), (toks, x),
              "embed_bwd", variant=tag, batch=mb, seq=seq)
    fn = sds((cfg.d_model,))
    ex.export(f"head_{tag}_mb{mb}n{seq}",
              lambda f_, e, xx, t: stages.head_fwd(cfg, f_, e, xx, t),
              (fn, emb, x, toks), "head_fwd", variant=tag, batch=mb, seq=seq)
    ex.export(f"head_bwd_{tag}_mb{mb}n{seq}",
              lambda f_, e, xx, t: stages.head_bwd(cfg, f_, e, xx, t),
              (fn, emb, x, toks), "head_bwd", variant=tag, batch=mb, seq=seq)


def exp_sp(ex: Exporter, b, h, c_local, dk, dv, sp_sizes=(2, 4, 8)):
    """LASP kernel-level primitives (paper Alg. 1/2) + hybrid attention SP."""
    q = sds((b, h, c_local, dk))
    v = sds((b, h, c_local, dv))
    g_s = sds((b, h, c_local))
    g_v = sds((b, h, c_local, dk))
    m = sds((b, h, dk, dv))
    shapes = {"none": None, "scalar": g_s, "vector": g_v}
    for kind, gs in shapes.items():
        if kind == "none":
            ex.export(f"sp_state_{kind}",
                      lambda k_, v_: stages.sp_state("none", k_, v_, None),
                      (q, v), "sp_state", gate_kind=kind,
                      batch=b, heads=h, chunk=c_local, dk=dk, dv=dv)
            ex.export(f"sp_output_{kind}",
                      lambda q_, k_, v_, m_: stages.sp_output(
                          "none", q_, k_, v_, None, m_),
                      (q, q, v, m), "sp_output", gate_kind=kind,
                      batch=b, heads=h, chunk=c_local, dk=dk, dv=dv)
        else:
            ex.export(f"sp_state_{kind}",
                      lambda k_, v_, g_, kk=kind: stages.sp_state(kk, k_, v_, g_),
                      (q, v, gs), "sp_state", gate_kind=kind,
                      batch=b, heads=h, chunk=c_local, dk=dk, dv=dv)
            ex.export(f"sp_output_{kind}",
                      lambda q_, k_, v_, g_, m_, kk=kind: stages.sp_output(
                          kk, q_, k_, v_, g_, m_),
                      (q, q, v, gs, m), "sp_output", gate_kind=kind,
                      batch=b, heads=h, chunk=c_local, dk=dk, dv=dv)
    for t in sp_sizes:
        kf = sds((b, h, c_local * t, dk))
        vf = sds((b, h, c_local * t, dv))
        ex.export(f"attn_sp_t{t}",
                  lambda q_, k_, v_, p0: stages.attn_sp(q_, k_, v_, p0),
                  (q, kf, vf, sds((), I32)), "attn_sp", sp_size=t,
                  batch=b, heads=h, chunk=c_local, dk=dk, dv=dv)


def exp_moe(ex: Exporter, name, tokens, d, e, f, top_k, cap, tile):
    cfg = ModelConfig(vocab=64, d_model=d, n_heads=1, d_head=d, n_layers=1,
                      layout="L", n_experts=e, top_k=top_k, d_ffn=f)
    ex.export(f"moe_router_{name}",
              lambda w, x: stages.moe_router(cfg, w, x),
              (sds((d, e)), sds((tokens, d))), "moe_router",
              tokens=tokens, d_model=d, n_experts=e, top_k=top_k)
    ex.export(f"moe_expert_cap_{name}",
              stages.moe_expert,
              (sds((d, f)), sds((d, f)), sds((f, d)), sds((cap, d))),
              "moe_expert", group=cap, d_model=d, d_ffn=f)
    ex.export(f"moe_expert_tile_{name}",
              stages.moe_expert,
              (sds((d, f)), sds((d, f)), sds((f, d)), sds((tile, d))),
              "moe_expert", group=tile, d_model=d, d_ffn=f)
    for e_local in sorted({e, e // 2, e // 4, e // 8} - {0}):
        ex.export(f"moe_grouped_{name}_e{e_local}",
                  stages.moe_grouped,
                  (sds((e_local, d, f)), sds((e_local, d, f)),
                   sds((e_local, f, d)), sds((e_local, cap, d))),
                  "moe_grouped", n_local=e_local, group=cap, d_model=d,
                  d_ffn=f)


def exp_adam(ex: Exporter, sizes=(65536, 4096)):
    for n in sizes:
        s = sds((n,))
        ex.export(
            f"adam_bucket_{n}",
            lambda p, g, m, v, st, lr: model_mod.adam_update(
                p, g, m, v, st, lr),
            (s, s, s, s, sds((), I32), sds((), F32)),
            "adam", bucket=n)


# ------------------------------- main ---------------------------------------

TABLE3_SHAPES = ((8, 256), (4, 512), (2, 1024), (1, 2048))
FIG5_STAIRCASE = (128, 256, 512, 1024, 2048, 4096)


def build(ex: Exporter, sets):
    if "core" in sets:
        # test-gating set: tiny variants, every instance + attn + one hybrid
        for inst in INSTANCES:
            exp_model(ex, "tiny", inst, "pure", 2, 128,
                      ("init", "train_step", "fwd_bwd", "eval_loss"))
        exp_model(ex, "tiny", "gla", "attn", 2, 128,
                  ("init", "train_step", "fwd_bwd", "eval_loss"))
        exp_model(ex, "tiny", "gla", "hybrid", 2, 128,
                  ("init", "train_step", "fwd_bwd", "eval_loss"))
        # monolith twin of the pipeline decomposition (integration test)
        exp_model(ex, "tiny", "gla", "pure", 1, 128, ("fwd_bwd",))
    if "table3" in sets:
        for inst in INSTANCES:
            for b, n in TABLE3_SHAPES:
                exp_model(ex, "tiny", inst, "pure", b, n, ("train_step",))
        for b, n in TABLE3_SHAPES:
            exp_model(ex, "tiny", "gla", "attn", b, n, ("train_step",))
    if "decode" in sets:
        for inst in INSTANCES:
            exp_decode(ex, "tiny", inst, "pure", 4)
        for n in FIG5_STAIRCASE:
            exp_decode(ex, "tiny", "gla", "attn", 4, max_n=n)
        exp_decode(ex, "tiny", "gla", "hybrid", 4, max_n=FIG5_STAIRCASE[-1])
    if "pipeline" in sets:
        exp_pipeline(ex, "tiny", "gla", 1, 128)
    if "sp" in sets:
        exp_sp(ex, 1, 2, 256, 64, 64)
    if "moe" in sets:
        exp_moe(ex, "tiny", tokens=256, d=128, e=4, f=128, top_k=2,
                cap=192, tile=32)
        exp_moe(ex, "bench", tokens=512, d=256, e=8, f=256, top_k=2,
                cap=192, tile=64)
    if "adam" in sets:
        exp_adam(ex)
    if "small" in sets:
        for inst in INSTANCES:
            for arch in ("pure", "hybrid"):
                exp_model(ex, "small", inst, arch, 4, 256,
                          ("init", "train_step", "eval_loss"))
        exp_model(ex, "small", "gla", "attn", 4, 256,
                  ("init", "train_step", "eval_loss"))
    if "small-decode" in sets:
        exp_decode(ex, "small", "bla", "pure", 2)
        exp_decode(ex, "small", "gla", "pure", 2)
        for n in FIG5_STAIRCASE:
            exp_decode(ex, "small", "gla", "attn", 2, max_n=n)


ALL_SETS = ("core", "table3", "decode", "pipeline", "sp", "moe", "adam",
            "small", "small-decode")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sets", default="all",
                    help="comma list of: " + ",".join(ALL_SETS))
    args = ap.parse_args()
    sets = ALL_SETS if args.sets == "all" else tuple(args.sets.split(","))
    ex = Exporter(args.out_dir)
    t0 = time.time()
    build(ex, sets)
    ex.write_manifest()
    print(f"total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
