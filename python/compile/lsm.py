# L2: LSM token-mixing layers (paper Fig. 1, "LSM layer").
#
# Every instance shares the frame: project q/k/v (+ instance gates) from
# the block input, run the chunkwise kernel from kernels/pallas_lsm.py,
# per-head RMS-normalize the output, apply a swish output gate for the
# gated instances, and project back to d_model.  The instance-specific
# part is exactly the gate parameterization feeding the unified recurrence
# M_s = Theta_s <> M_{s-1} + k_s^T v_s (paper Eq. 5 / Table 1).
#
# Gate parameterizations (DESIGN.md "numerics policy"):
#   - vector gates (GLA / HGRN2 / RWKV6): log(alpha) = -GATE_CAP*sigmoid(z),
#     satisfying the chunked-kernel stability bound exactly.
#   - scalar gates (Mamba2): alpha = exp(-softplus(A) * dt), dt = softplus;
#     the scalar kernel's pairwise-ratio form is stable for any strength.
#   - Retention: fixed per-head decay a_h = 1 - 2^(-5-h) (RetNet).
#   - DeltaNet: k L2-normalized, beta = sigmoid.

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attn as attn_kernel
from .kernels import chunked, pallas_lsm, ref
from .kernels.chunked import GATE_CAP

INSTANCES = ("bla", "retention", "gla", "deltanet", "mamba2", "hgrn2", "rwkv6")
GATED_OUTPUT = {"gla", "mamba2", "hgrn2", "rwkv6"}   # swish output gate
GATE_KIND = {
    "bla": "none", "retention": "scalar", "gla": "vector",
    "deltanet": "beta", "mamba2": "scalar", "hgrn2": "vector",
    "rwkv6": "vector",
}


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _dense(key, shape, scale=None):
    fan_in = shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * scale


def retention_decay(n_heads):
    """RetNet per-head decay: a_h = 1 - 2^{-5-h}."""
    return jnp.array([1.0 - 2.0 ** (-5.0 - h) for h in range(n_heads)],
                     jnp.float32)


def init_lsm_params(key, cfg: ModelConfig):
    """Parameters for one LSM token-mixing layer of instance cfg.lsm."""
    inst = cfg.lsm
    d, dq = cfg.d_model, cfg.d_qkv
    keys = iter(jax.random.split(key, 12))
    p = {
        "wq": _dense(next(keys), (d, dq)),
        "wv": _dense(next(keys), (d, dq)),
        "wo": _dense(next(keys), (dq, d)),
        "onorm": jnp.ones((cfg.n_heads, cfg.d_head), jnp.float32),
    }
    if inst != "hgrn2":                       # hgrn2 ties k to the gate
        p["wk"] = _dense(next(keys), (d, dq))
    if inst in ("gla", "hgrn2", "rwkv6"):     # vector gate
        p["wa"] = _dense(next(keys), (d, dq))
        p["ba"] = jnp.zeros((dq,), jnp.float32)
    if inst == "mamba2":                      # scalar per-head decay + dt
        p["wdt"] = _dense(next(keys), (d, cfg.n_heads))
        p["bdt"] = jnp.full((cfg.n_heads,), 0.5, jnp.float32)
        p["a_log"] = jnp.zeros((cfg.n_heads,), jnp.float32)
    if inst == "deltanet":
        p["wb"] = _dense(next(keys), (d, cfg.n_heads))
    if inst == "rwkv6":                       # token-shift mix coefficient
        p["mu"] = jnp.full((d,), 0.5, jnp.float32)
    if inst in GATED_OUTPUT:
        p["wg"] = _dense(next(keys), (d, dq))
    return p


def _split_heads(t, h):
    b, n, hd = t.shape
    return t.reshape(b, n, h, hd // h).transpose(0, 2, 1, 3)


def _merge_heads(t):
    b, h, n, dh = t.shape
    return t.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _gates(cfg: ModelConfig, p, x, xs):
    """Instance-specific (q, k, v, gates, beta) from block input x (B,N,d).
    xs is the token-shifted input (for rwkv6)."""
    inst, h = cfg.lsm, cfg.n_heads
    xin = xs if inst == "rwkv6" else x
    q = _split_heads(xin @ p["wq"], h)
    v = _split_heads(xin @ p["wv"], h)
    gates = beta = None
    if inst == "hgrn2":
        a = jnp.exp(-GATE_CAP * jax.nn.sigmoid(xin @ p["wa"] + p["ba"]))
        gates = _split_heads(a, h)
        k = 1.0 - gates
    else:
        k = _split_heads(xin @ p["wk"], h)
    if inst in ("gla", "rwkv6"):
        a = jnp.exp(-GATE_CAP * jax.nn.sigmoid(xin @ p["wa"] + p["ba"]))
        gates = _split_heads(a, h)
    elif inst == "retention":
        dec = retention_decay(h)              # (H,)
        b_, n_ = x.shape[0], x.shape[1]
        gates = jnp.broadcast_to(dec[None, :, None], (b_, h, n_))
    elif inst == "mamba2":
        dt = jax.nn.softplus(xin @ p["wdt"] + p["bdt"])       # (B,N,H)
        a = jax.nn.softplus(p["a_log"])                        # (H,)
        gates = jnp.exp(-a[None, None, :] * dt).transpose(0, 2, 1)
        # Mamba2 writes b_s k^T v: fold dt into k.
        k = k * dt.transpose(0, 2, 1)[..., None]
    elif inst == "deltanet":
        beta = jax.nn.sigmoid(xin @ p["wb"]).transpose(0, 2, 1)  # (B,H,N)
        k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
        q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    return q, k, v, gates, beta


def _token_shift(x, mu, x_prev=None):
    """RWKV-style token shift: mix each token with its predecessor."""
    if x_prev is None:
        shifted = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)
    return x + mu * (shifted - x)


def lsm_layer(cfg: ModelConfig, p, x, m0=None, backend="pallas"):
    """Apply the LSM token-mixing layer.  x: (B, N, d_model).
    Returns (y, m_final).  backend: pallas | chunked | ref."""
    inst = cfg.lsm
    xs = _token_shift(x, p["mu"]) if inst == "rwkv6" else x
    q, k, v, gates, beta = _gates(cfg, p, x, xs)
    kind = GATE_KIND[inst]

    if backend == "pallas":
        # lsm_ad = Pallas forward + recompute-chunked backward (custom_vjp)
        # so the same call site serves training and inference artifacts.
        o, m = pallas_lsm.lsm_ad(kind, cfg.chunk, q, k, v, gates, beta, m0)
    elif backend == "chunked":
        if kind == "none":
            o, m = chunked.bla(q, k, v, cfg.chunk, m0)
        elif kind == "scalar":
            o, m = chunked.simple_decay(q, k, v, gates, cfg.chunk, m0)
        elif kind == "vector":
            o, m = chunked.vector_decay(q, k, v, gates, cfg.chunk, m0)
        elif kind == "beta":
            o, m = chunked.delta_rule(q, k, v, beta, cfg.chunk, m0)
    elif backend == "ref":
        if kind == "none":
            o, m = ref.bla(q, k, v, m0)
        elif kind == "scalar":
            o, m = ref.simple_decay(q, k, v, gates, m0)
        elif kind == "vector":
            o, m = ref.vector_decay(q, k, v, gates, m0)
        elif kind == "beta":
            o, m = ref.delta_rule(q, k, v, beta, m0)
    else:
        raise ValueError(backend)

    o = rms_norm(o, p["onorm"][None, :, None, :], cfg.rms_eps)
    o = _merge_heads(o)
    if inst in GATED_OUTPUT:
        o = o * jax.nn.silu(xs @ p["wg"])
    return o @ p["wo"], m


def lsm_layer_decode(cfg: ModelConfig, p, x_t, m, x_prev=None):
    """Single-token decode step.  x_t: (B, d).  m: (B, H, Dk, Dv).
    Returns (y_t, m_new, x_t-for-shift).  Constant time & memory -- this is
    the paper's linear-inference claim (Fig. 5)."""
    inst = cfg.lsm
    x = x_t[:, None, :]                      # (B, 1, d)
    if inst == "rwkv6":
        xs = _token_shift(x, p["mu"], x_prev)
    else:
        xs = x
    q, k, v, gates, beta = _gates(cfg, p, x, xs)
    kind = GATE_KIND[inst]
    # One-token recurrence update (ref.py math, no scan needed).
    qs, ks, vs = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    if kind == "none":
        m_new = m + ks[..., :, None] * vs[..., None, :]
    elif kind == "scalar":
        a = gates[:, :, 0]
        m_new = a[..., None, None] * m + ks[..., :, None] * vs[..., None, :]
    elif kind == "vector":
        a = gates[:, :, 0]
        m_new = a[..., :, None] * m + ks[..., :, None] * vs[..., None, :]
    elif kind == "beta":
        b = beta[:, :, 0]
        km = jnp.einsum("bhk,bhkv->bhv", ks, m)
        m_new = m + b[..., None, None] * ks[..., :, None] * (vs - km)[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", qs, m_new)          # (B, H, Dv)
    o = rms_norm(o, p["onorm"][None], cfg.rms_eps)
    o = o.reshape(x_t.shape[0], -1)
    if inst in GATED_OUTPUT:
        o = o * jax.nn.silu(xs[:, 0] @ p["wg"])
    return o @ p["wo"], m_new, x_t


# ---------------------------------------------------------------------------
# Standard softmax-attention layer ('N' layers in hybrid stacks; the
# quadratic Baseline).  RoPE position encoding, flash-style Pallas kernel.
# ---------------------------------------------------------------------------


def init_attn_params(key, cfg: ModelConfig):
    d, dq = cfg.d_model, cfg.d_qkv
    keys = jax.random.split(key, 4)
    return {
        "wq": _dense(keys[0], (d, dq)),
        "wk": _dense(keys[1], (d, dq)),
        "wv": _dense(keys[2], (d, dq)),
        "wo": _dense(keys[3], (dq, d)),
    }


def rope(x, pos, theta):
    """x: (B, H, N, Dh), pos: (N,) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]   # (N, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attn_layer(cfg: ModelConfig, p, x, backend="pallas", pos0=0):
    """Standard causal self-attention layer.  x: (B, N, d)."""
    h = cfg.n_heads
    n = x.shape[1]
    q = _split_heads(x @ p["wq"], h)
    k = _split_heads(x @ p["wk"], h)
    v = _split_heads(x @ p["wv"], h)
    pos = pos0 + jnp.arange(n, dtype=jnp.int32)
    q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
    if backend == "pallas":
        o = attn_kernel.attention_ad(q, k, v, min(cfg.chunk, n), None)
    else:
        o = ref.softmax_attention(q, k, v)
    return _merge_heads(o) @ p["wo"]


def attn_layer_decode(cfg: ModelConfig, p, x_t, kcache, vcache, pos):
    """KV-cache decode step.  x_t: (B, d); caches: (B, H, Nmax, Dh);
    pos: scalar int32 index of the current token.  Cost grows with the
    cache length -- the quadratic comparator for Fig. 5."""
    h = cfg.n_heads
    b = x_t.shape[0]
    q = (x_t @ p["wq"]).reshape(b, h, 1, cfg.d_head)
    k = (x_t @ p["wk"]).reshape(b, h, 1, cfg.d_head)
    v = (x_t @ p["wv"]).reshape(b, h, 1, cfg.d_head)
    posv = jnp.full((1,), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)[:, :, 0]
    k = rope(k, posv, cfg.rope_theta)[:, :, 0]
    kcache = jax.lax.dynamic_update_index_in_dim(kcache, k, pos, 2)
    vcache = jax.lax.dynamic_update_index_in_dim(vcache, v[:, :, 0], pos, 2)
    nmax = kcache.shape[2]
    s = jnp.einsum("bhd,bhnd->bhn", q, kcache) * (cfg.d_head ** -0.5)
    mask = jnp.arange(nmax)[None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    pweights = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhn,bhnv->bhv", pweights, vcache).reshape(b, -1)
    return o @ p["wo"], kcache, vcache
