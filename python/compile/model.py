# L2: the Linear-MoE model (paper Fig. 1).
#
# L x stacked blocks; each block = (RMSNorm -> token mixer -> residual) +
# (RMSNorm -> MoE layer -> residual).  The mixer is the LSM layer for 'L'
# positions in the layout string and standard softmax attention for 'N'
# positions (hybrid models, paper §2.1.2).  Embeddings are tied to the LM
# head.  Training objective: next-token cross-entropy + switch aux loss.
#
# Everything here is lowered to HLO text by aot.py and executed from Rust;
# Python never runs at training/inference time.

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import lsm as lsm_mod
from . import moe as moe_mod
from .lsm import rms_norm


def init_params(cfg: ModelConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = []
    for i, ch in enumerate(cfg.layout):
        k_mix, k_moe = jax.random.split(layer_keys[i])
        mixer = (lsm_mod.init_lsm_params(k_mix, cfg) if ch == "L"
                 else lsm_mod.init_attn_params(k_mix, cfg))
        layers.append({
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "mixer": mixer,
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "moe": moe_mod.init_moe_params(k_moe, cfg),
        })
    return {
        "embed": jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def block_apply(cfg: ModelConfig, ch, lp, x, backend="pallas",
                moe_strategy="grouped", pos0=0):
    """One Linear-MoE / attention-MoE block.  x: (B, N, d)."""
    h = rms_norm(x, lp["ln1"], cfg.rms_eps)
    if ch == "L":
        y, _ = lsm_mod.lsm_layer(cfg, lp["mixer"], h, backend=backend)
    else:
        y = lsm_mod.attn_layer(cfg, lp["mixer"], h, backend=backend,
                               pos0=pos0)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.rms_eps)
    y, aux = moe_mod.moe_layer(cfg, lp["moe"], h, strategy=moe_strategy)
    return x + y, aux


def forward(cfg: ModelConfig, params, tokens, backend="pallas",
            moe_strategy="grouped"):
    """tokens: (B, N) int32 -> (logits (B, N, V), aux_loss)."""
    x = params["embed"][tokens]
    aux_total = 0.0
    for i, ch in enumerate(cfg.layout):
        x, aux = block_apply(cfg, ch, params["layers"][i], x,
                             backend=backend, moe_strategy=moe_strategy)
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = x @ params["embed"].T
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params, tokens, targets, backend="pallas",
            moe_strategy="grouped"):
    """Next-token CE + aux.  targets < 0 are masked (padding / packing
    boundaries, paper §2.2.4)."""
    logits, aux = forward(cfg, params, tokens, backend, moe_strategy)
    mask = (targets >= 0).astype(jnp.float32)
    tsafe = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tsafe[..., None], axis=-1)[..., 0]
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + cfg.aux_loss_coef * aux, ce


def fwd_bwd(cfg: ModelConfig, params, tokens, targets, backend="pallas",
            moe_strategy="grouped"):
    """(loss, ce, grads) -- the per-worker unit of data parallelism: Rust
    all-reduces `grads` across DP ranks before the optimizer step."""
    (loss, ce), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets, backend, moe_strategy),
        has_aux=True)(params)
    return loss, ce, grads


# ---------------------------------------------------------------------------
# Adam (the optimizer state lives in Rust between steps; this is the pure
# update rule, also exported per flat bucket for the ZeRO-1 distributed
# optimizer -- see aot.py `adam_bucket`).
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def adam_update(p, g, m, v, step, lr):
    """step: int32 scalar (1-based), lr: f32 scalar.  Pytree-polymorphic."""
    step_f = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** step_f
    bc2 = 1.0 - ADAM_B2 ** step_f

    flat_p, treedef = jax.tree_util.tree_flatten(p)
    flat_g = treedef.flatten_up_to(g)
    flat_m = treedef.flatten_up_to(m)
    flat_v = treedef.flatten_up_to(v)
    new_p, new_m, new_v = [], [], []
    for pi, gi, mi, vi in zip(flat_p, flat_g, flat_m, flat_v):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * gi
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * gi * gi
        mhat = mi / bc1
        vhat = vi / bc2
        new_p.append(pi - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    unflat = jax.tree_util.tree_unflatten
    return unflat(treedef, new_p), unflat(treedef, new_m), unflat(treedef, new_v)


def train_step(cfg: ModelConfig, params, m, v, step, lr, tokens, targets,
               backend="pallas", moe_strategy="grouped"):
    """Fused single-worker train step: fwd + bwd + Adam.
    Returns (loss, ce, new_params, new_m, new_v)."""
    loss, ce, grads = fwd_bwd(cfg, params, tokens, targets, backend,
                              moe_strategy)
    new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
    return loss, ce, new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Decode (paper Fig. 5): LSM layers carry a constant-size (Dk, Dv) state
# per head; attention layers carry a growing KV cache.  One artifact per
# (variant, cache size); the Rust inference driver owns the loop.
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch, max_n=None):
    """Per-layer decode state.  For 'L': {m, xprev}; for 'N': {k, v}."""
    states = []
    for ch in cfg.layout:
        if ch == "L":
            states.append({
                "m": jnp.zeros((batch, cfg.n_heads, cfg.d_head, cfg.d_head),
                               jnp.float32),
                "xprev": jnp.zeros((batch, cfg.d_model), jnp.float32),
            })
        else:
            assert max_n is not None, "hybrid decode needs max_n"
            states.append({
                "k": jnp.zeros((batch, cfg.n_heads, max_n, cfg.d_head),
                               jnp.float32),
                "v": jnp.zeros((batch, cfg.n_heads, max_n, cfg.d_head),
                               jnp.float32),
            })
    return states


def decode_step(cfg: ModelConfig, params, states, token, pos):
    """One decode step.  token: (B,) int32; pos: scalar int32.
    Returns (logits (B, V), new_states)."""
    x = params["embed"][token]                    # (B, d)
    new_states = []
    for i, ch in enumerate(cfg.layout):
        lp = params["layers"][i]
        st = states[i]
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        if ch == "L":
            y, m_new, xprev = lsm_mod.lsm_layer_decode(
                cfg, lp["mixer"], h, st["m"], st["xprev"])
            new_states.append({"m": m_new, "xprev": xprev})
        else:
            y, kc, vc = lsm_mod.attn_layer_decode(
                cfg, lp["mixer"], h, st["k"], st["v"], pos)
            new_states.append({"k": kc, "v": vc})
        x = x + y
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        y, _ = moe_mod.moe_layer(cfg, lp["moe"], h[:, None, :],
                                 strategy="grouped")
        x = x + y[:, 0]
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x @ params["embed"].T, new_states


def param_count(cfg: ModelConfig):
    """(total, activated) parameter counts -- paper's AxB-yB naming."""
    p = init_params(cfg)
    total = sum(x.size for x in jax.tree_util.tree_leaves(p))
    moe_total = sum(
        x.size for lp in p["layers"] for x in jax.tree_util.tree_leaves(
            lp["moe"]))
    moe_active = 0
    for lp in p["layers"]:
        mp = lp["moe"]
        per_exp = (mp["w1"].size + mp["w2"].size + mp["w3"].size) // cfg.n_experts
        moe_active += mp["router"].size + per_exp * cfg.top_k
    return total, total - moe_total + moe_active
