# L2: Mixture-of-Experts layer (paper Fig. 1, "MoE layer").
#
# Standard top-k softmax routing with a switch-style auxiliary
# load-balancing loss, SwiGLU experts, and capacity-based token dispatch --
# the mechanisms the paper keeps from SOTA open-source MoE (Qwen2-MoE).
#
# Three execution strategies reproduce Table 4 (top):
#   dense   : every expert over every token (oracle; E x FLOPs).
#   loop    : capacity dispatch, then a python loop over experts -> E small
#             matmul chains in the HLO (the naive Megatron baseline).
#   grouped : the same dispatch, one batched einsum over (E, cap, d) -- the
#             GroupedGEMM analogue.
# The MegaBlocks analogue lives in the Rust coordinator (exact-fit tiled
# dispatch over the `moe_expert_tile` artifact; see coordinator/moe.rs) --
# its defining trait is *dynamic* group sizes, which static HLO cannot
# express.
#
# All strategies are numerically identical up to dropped-token handling
# (dense drops nothing; loop/grouped drop tokens past expert capacity).

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _dense_init(key, shape):
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(shape[-2])


def init_moe_params(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ffn, cfg.n_experts
    keys = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(keys[0], (d, e), jnp.float32) * 0.02,
        "w1": _dense_init(keys[1], (e, d, f)),   # gate proj
        "w3": _dense_init(keys[2], (e, d, f)),   # up proj
        "w2": _dense_init(keys[3], (e, f, d)),   # down proj
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Static per-expert capacity."""
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(c, cfg.top_k)


def _topk(probs, k):
    """Iterative-argmax top-k.  jax.lax.top_k lowers to the HLO `topk`
    instruction, which the xla_extension 0.5.1 text parser (the Rust
    runtime's XLA) does not know; k is small (2-8) so k argmax sweeps lower
    to plain reduces and cost the same.  Returns (values, indices)."""
    vals, idxs = [], []
    masked = probs
    for _ in range(k):
        i = jnp.argmax(masked, axis=-1)
        v = jnp.take_along_axis(masked, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        masked = masked * (1.0 - jax.nn.one_hot(i, probs.shape[-1],
                                                dtype=probs.dtype)) - \
            jax.nn.one_hot(i, probs.shape[-1], dtype=probs.dtype)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def route(cfg: ModelConfig, p, x):
    """Top-k routing.  x: (T, d).
    Returns (gates (T,k), idx (T,k) int32, aux_loss scalar)."""
    logits = x @ p["router"]                       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = _topk(probs, cfg.top_k)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    # Switch-Transformer aux loss: E * sum_e f_e * P_e
    one_hot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)          # (E,)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * p_e) / cfg.top_k
    return gates, idx, aux


def _expert_ffn(xe, w1, w3, w2):
    """SwiGLU expert.  xe: (..., d)."""
    return (jax.nn.silu(xe @ w1) * (xe @ w3)) @ w2


def _dispatch(cfg: ModelConfig, x, gates, idx, cap):
    """Capacity-based dispatch.  Returns (buf (E, cap, d), slot (T,k),
    keep (T,k)).  Tokens past capacity are dropped (slot -> scrap row)."""
    t = x.shape[0]
    e = cfg.n_experts
    flat_idx = idx.reshape(-1)                                  # (T*k,)
    # Position of each assignment within its expert, in token order.
    one_hot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)      # (T*k, E)
    pos_in_e = jnp.cumsum(one_hot, axis=0) - 1                  # (T*k, E)
    slot = jnp.take_along_axis(pos_in_e, flat_idx[:, None], 1)[:, 0]
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)                         # cap = scrap
    buf = jnp.zeros((e, cap + 1, x.shape[1]), x.dtype)
    tok = jnp.repeat(jnp.arange(t), cfg.top_k)
    buf = buf.at[flat_idx, slot_c].set(x[tok])
    return buf, slot_c.reshape(idx.shape), keep.reshape(idx.shape)


def _combine(x, out, idx, slot, keep, gates):
    """Gather expert outputs back to token order and mix by gate weight."""
    t, k = idx.shape
    flat = out[idx.reshape(-1), slot.reshape(-1)].reshape(t, k, -1)
    flat = flat * (gates * keep)[..., None]
    return jnp.sum(flat, axis=1)


def moe_layer(cfg: ModelConfig, p, x, strategy="grouped"):
    """MoE layer.  x: (B, N, d) -> (y, aux_loss)."""
    b, n, d = x.shape
    xt = x.reshape(b * n, d)
    gates, idx, aux = route(cfg, p, xt)

    if strategy == "dense":
        # (E, T, f) -- every expert everywhere; exact, no drops.
        y_all = jax.vmap(_expert_ffn, in_axes=(None, 0, 0, 0))(
            xt, p["w1"], p["w3"], p["w2"])                     # (E, T, d)
        one_hot = jax.nn.one_hot(idx, cfg.n_experts,
                                 dtype=jnp.float32)        # (T,k,E)
        w = jnp.einsum("tk,tke->et", gates, one_hot)
        y = jnp.einsum("et,etd->td", w, y_all)
        return y.reshape(b, n, d), aux

    cap = capacity(cfg, b * n)
    buf, slot, keep = _dispatch(cfg, xt, gates, idx, cap)
    if strategy == "grouped":
        out = _expert_ffn(buf, p["w1"], p["w3"], p["w2"])       # batched
    elif strategy == "loop":
        outs = [
            _expert_ffn(buf[e], p["w1"][e], p["w3"][e], p["w2"][e])
            for e in range(cfg.n_experts)
        ]
        out = jnp.stack(outs)
    else:
        raise ValueError(f"unknown MoE strategy {strategy!r}")
    y = _combine(xt, out, idx, slot, keep, gates)
    return y.reshape(b, n, d), aux


# --- pieces lowered as standalone artifacts for the Rust EP dispatcher ----


def router_fn(cfg: ModelConfig, router_w, x):
    """Standalone router for expert-parallel dispatch in Rust.
    x: (T, d) -> (gates (T,k), idx (T,k) int32)."""
    logits = x @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = _topk(probs, cfg.top_k)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return gates, idx.astype(jnp.int32)


def expert_tile_fn(w1, w3, w2, xt):
    """One expert over one tile of tokens -- the MegaBlocks-analogue unit
    the Rust coordinator schedules per occupied tile.  xt: (TILE, d)."""
    return _expert_ffn(xt, w1, w3, w2)
