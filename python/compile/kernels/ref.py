# Pure-jnp sequential oracles for every LSM instance (paper Table 1).
#
# These are the *ground truth* for the chunked formulations in chunked.py
# and the Pallas kernels in pallas_lsm.py: each one executes the unified
# recurrence   M_s = Theta_s <> M_{s-1} + f(k_s^T, v_s)   (paper Eq. 5)
# token-by-token with jax.lax.scan, exactly as written in the paper.
#
# Shape conventions (all functions):
#   q, k : (B, H, N, Dk)      v : (B, H, N, Dv)
#   scalar gates  : (B, H, N)          -- per-token scalar decay
#   vector gates  : (B, H, N, Dk)      -- per-token per-dim decay
#   beta          : (B, H, N)          -- delta-rule write strength
#   returns (o, M_final) with o : (B, H, N, Dv), M_final : (B, H, Dk, Dv)
#
# All oracles accept an optional initial state `m0 : (B, H, Dk, Dv)` so the
# LASP sequence-parallel decomposition (chunk-local state + prefix state)
# can be validated against them.

import jax
import jax.numpy as jnp


def _scan_heads(step, q, k, v, extras, m0):
    """Run `step` over the token axis with scan; extras is a tuple of
    per-token tensors each shaped (B, H, N, ...)."""
    B, H, N, Dk = k.shape
    Dv = v.shape[-1]
    if m0 is None:
        m0 = jnp.zeros((B, H, Dk, Dv), dtype=jnp.float32)
    # scan over the token axis: move N to the front.
    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (q, k, v) + tuple(extras))

    def body(m, ts):
        o, m_new = step(m, *ts)
        return m_new, o

    m_final, o = jax.lax.scan(body, m0, xs)
    return jnp.moveaxis(o, 0, 2), m_final


def bla(q, k, v, m0=None):
    """Basic linear attention:  M_s = M_{s-1} + k_s^T v_s,  o_s = q_s M_s."""

    def step(m, qs, ks, vs):
        m = m + ks[..., :, None] * vs[..., None, :]
        return jnp.einsum("bhk,bhkv->bhv", qs, m), m

    return _scan_heads(step, q, k, v, (), m0)


def simple_decay(q, k, v, alpha, m0=None):
    """Scalar-decay linear attention (Lightning Attn / RetNet / Mamba2):
    M_s = a_s M_{s-1} + k_s^T v_s.  alpha : (B, H, N)."""

    def step(m, qs, ks, vs, a):
        m = a[..., None, None] * m + ks[..., :, None] * vs[..., None, :]
        return jnp.einsum("bhk,bhkv->bhv", qs, m), m

    return _scan_heads(step, q, k, v, (alpha,), m0)


def vector_decay(q, k, v, alpha, m0=None):
    """Vector-gated linear attention (GLA / HGRN2 / RWKV6):
    M_s = diag(a_s) M_{s-1} + k_s^T v_s.  alpha : (B, H, N, Dk)."""

    def step(m, qs, ks, vs, a):
        m = a[..., :, None] * m + ks[..., :, None] * vs[..., None, :]
        return jnp.einsum("bhk,bhkv->bhv", qs, m), m

    return _scan_heads(step, q, k, v, (alpha,), m0)


def delta_rule(q, k, v, beta, m0=None):
    """DeltaNet:  M_s = (I - b_s k_s^T k_s) M_{s-1} + b_s k_s^T v_s.
    Callers should L2-normalize k so (I - b k^T k) is a contraction."""

    def step(m, qs, ks, vs, b):
        # m <- m + b * k^T (v - k m)
        km = jnp.einsum("bhk,bhkv->bhv", ks, m)
        m = m + b[..., None, None] * ks[..., :, None] * (vs - km)[..., None, :]
        return jnp.einsum("bhk,bhkv->bhv", qs, m), m

    return _scan_heads(step, q, k, v, (beta,), m0)


def gated_delta_rule(q, k, v, alpha, beta, m0=None):
    """Gated DeltaNet:  M_s = a_s (I - b_s k_s^T k_s) M_{s-1} + b_s k_s^T v_s.
    alpha, beta : (B, H, N)."""

    def step(m, qs, ks, vs, a, b):
        m = a[..., None, None] * m
        km = jnp.einsum("bhk,bhkv->bhv", ks, m)
        m = m + b[..., None, None] * ks[..., :, None] * (vs - km)[..., None, :]
        return jnp.einsum("bhk,bhkv->bhv", qs, m), m

    return _scan_heads(step, q, k, v, (alpha, beta), m0)


def hgrn2(q, k, v, alpha, m0=None):
    """HGRN2:  M_s = diag(a_s) M_{s-1} + (1 - a_s)^T v_s.
    The input gate is tied to the forget gate: k_s = 1 - a_s.  `k` is
    ignored (pass anything shape-compatible); kept in the signature so all
    oracles share one calling convention."""
    return vector_decay(q, 1.0 - alpha, v, alpha, m0)


def softmax_attention(q, k, v, scale=None):
    """Causal softmax attention (the quadratic Baseline, paper Eq. 1-2)."""
    B, H, N, Dk = q.shape
    if scale is None:
        scale = Dk ** -0.5
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k) * scale
    mask = jnp.tril(jnp.ones((N, N), dtype=bool))
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhnm,bhmv->bhnv", p, v)


# Registry: instance name -> (oracle fn, gate kind).
# gate kinds: none | scalar | vector | beta | scalar+beta
ORACLES = {
    "bla": (bla, "none"),
    "retention": (simple_decay, "scalar"),
    "lightning": (simple_decay, "scalar"),
    "mamba2": (simple_decay, "scalar"),
    "gla": (vector_decay, "vector"),
    "rwkv6": (vector_decay, "vector"),
    "hgrn2": (hgrn2, "vector"),
    "deltanet": (delta_rule, "beta"),
    "gated_deltanet": (gated_delta_rule, "scalar+beta"),
}
