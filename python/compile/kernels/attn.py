# L1 baseline: chunked causal softmax attention as a Pallas kernel.
#
# This is the quadratic comparator for Table 3 / Fig 4 / Fig 5 (the paper's
# "Baseline" / FlashAttention-2 role).  Flash-style online softmax: grid
# over (batch*head, q-chunk); the kernel streams k/v chunks with a
# fori_loop, maintaining running max / normalizer, so the full (N, N)
# score matrix never materializes.
#
# interpret=True only (see pallas_lsm.py).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ref_attention(q, k, v, scale):
    n = q.shape[-2]
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k) * scale
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    s = jnp.where(mask, s, -jnp.inf)
    return jnp.einsum("bhnm,bhmv->bhnv", jax.nn.softmax(s, axis=-1), v)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, chunk, scale):
    qc = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale      # (1, C, Dk)
    dv = v_ref.shape[-1]
    c = q.shape[1]

    def body(kc, carry):
        acc, m_run, l_run = carry
        k = k_ref[0, pl.dslice(kc * chunk, chunk), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kc * chunk, chunk), :].astype(jnp.float32)
        s = q[0] @ k.T                              # (C, C)
        # causal mask: query index qc*C+i >= key index kc*C+j
        qi = qc * chunk + jax.lax.broadcasted_iota(jnp.int32, (c, chunk), 0)
        kj = kc * chunk + jax.lax.broadcasted_iota(jnp.int32, (c, chunk), 1)
        s = jnp.where(qi >= kj, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((c, dv), jnp.float32)
    m0 = jnp.full((c,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((c,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, qc + 1, body, (acc0, m0, l0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def softmax_attention(q, k, v, chunk=64, scale=None, interpret=True):
    """Causal softmax attention.  q,k:(B,H,N,Dk) v:(B,H,N,Dv) -> (B,H,N,Dv)."""
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    assert n % chunk == 0
    if scale is None:
        scale = dk ** -0.5
    bh, nq = b * h, n // chunk
    qf = q.reshape(bh, n, dk)
    kf = k.reshape(bh, n, dk)
    vf = v.reshape(bh, n, dv)

    o = pl.pallas_call(
        functools.partial(_attn_kernel, chunk=chunk, scale=scale),
        grid=(bh, nq),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            # whole-K/V residency per program: on real TPU this would be a
            # second kv grid axis; interpret-mode CPU makes streaming via
            # dslice equivalent and simpler.
            pl.BlockSpec((1, n, dk), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dv), v.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return o.reshape(b, h, n, dv)


# Differentiable wrapper (same recompute-backward pattern as
# pallas_lsm.lsm_ad; see that module's comment).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention_ad(q, k, v, chunk=64, scale=None):
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _ref_attention(q, k, v, scale)


def _attn_ad_fwd(q, k, v, chunk, scale):
    return softmax_attention(q, k, v, chunk=chunk, scale=scale), (q, k, v)


def _attn_ad_bwd(chunk, scale, res, ct):
    q, k, v = res
    s = scale if scale is not None else q.shape[-1] ** -0.5
    _, vjp = jax.vjp(lambda a, b, c: _ref_attention(a, b, c, s), q, k, v)
    return vjp(ct)


attention_ad.defvjp(_attn_ad_fwd, _attn_ad_bwd)
