# L1: Pallas kernels for the unified LSM recurrence (paper Eq. 5).
#
# One grid program per (batch*head, chunk).  The chunk axis is the
# *sequential* grid dimension: the carried memory state M lives in an
# output ref that every chunk step of the same (b,h) maps to the same
# block, so state flows chunk -> chunk exactly like the recurrence.  The
# within-chunk math is the chunkwise-parallel formulation from chunked.py
# (matmul-shaped => MXU-friendly on real TPU).
#
# TPU adaptation (DESIGN.md "Hardware-Adaptation"): the paper's Triton
# kernels tile for SRAM/warps; here BlockSpec expresses the HBM->VMEM
# schedule: per grid step the kernel touches q/k/v chunks of (C, D) plus
# the (Dk, Dv) state -- VMEM footprint = C*(2Dk+2Dv) + 2*Dk*Dv floats
# (~ 90 KB at C=64, D=128), far under the ~16 MB VMEM budget, and every
# inner op is a (C,Dk)x(Dk,C)/(C,C)x(C,Dv) matmul.
#
# MUST run with interpret=True: on CPU-PJRT, interpret-mode pallas_call
# traces the kernel body into plain HLO, which is what aot.py ships to the
# Rust runtime.  Real-TPU lowering emits a Mosaic custom-call the CPU
# plugin cannot execute.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import chunked


def _flatten_bh(t):
    b, h = t.shape[:2]
    return t.reshape(b * h, *t.shape[2:])


def _kernel_body(kind, q_ref, k_ref, v_ref, g_ref, b_ref, m0_ref, o_ref, m_ref):
    """Shared kernel body; g_ref / b_ref are None for instances without
    that gate.  Block shapes carry a leading 1 (the bh axis)."""
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        m_ref[...] = m0_ref[...]

    m = m_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)

    if kind == "none":
        o, m_new = chunked.chunk_bla(q, k, v, m)
    elif kind == "scalar":
        g = jnp.log(g_ref[...].astype(jnp.float32))
        o, m_new = chunked.chunk_scalar_decay(q, k, v, g, m)
    elif kind == "vector":
        g = jnp.log(g_ref[...].astype(jnp.float32))
        o, m_new = chunked.chunk_vector_decay(q, k, v, g, m)
    elif kind == "beta":
        beta = b_ref[...].astype(jnp.float32)
        o, m_new = chunked.chunk_delta(q, k, v, beta, m)
    elif kind == "scalar+beta":
        g = jnp.log(g_ref[...].astype(jnp.float32))
        beta = b_ref[...].astype(jnp.float32)
        o, m_new = chunked.chunk_gated_delta(q, k, v, g, beta, m)
    else:
        raise ValueError(f"unknown gate kind {kind!r}")

    o_ref[...] = o.astype(o_ref.dtype)
    m_ref[...] = m_new


def lsm_pallas(kind, q, k, v, gates=None, beta=None, chunk=64, m0=None,
               interpret=True):
    """Run the chunkwise LSM kernel.

    kind  : 'none' | 'scalar' | 'vector' | 'beta' | 'scalar+beta'
    q, k  : (B, H, N, Dk)   v : (B, H, N, Dv)
    gates : (B, H, N) scalar-decay alpha or (B, H, N, Dk) vector alpha
    beta  : (B, H, N) delta write strength
    m0    : (B, H, Dk, Dv) initial state (zeros when None)
    Returns (o : (B, H, N, Dv), m_final : (B, H, Dk, Dv)).
    """
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    assert n % chunk == 0, f"N={n} % chunk={chunk} != 0"
    bh, nc = b * h, n // chunk
    if m0 is None:
        m0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    qf, kf, vf = _flatten_bh(q), _flatten_bh(k), _flatten_bh(v)
    m0f = _flatten_bh(m0)

    chunk_spec = lambda d: pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0))
    state_spec = pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0))

    operands = [qf, kf, vf]
    in_specs = [chunk_spec(dk), chunk_spec(dk), chunk_spec(dv)]
    has_g = kind in ("scalar", "vector", "scalar+beta")
    has_b = kind in ("beta", "scalar+beta")
    if has_g:
        gf = _flatten_bh(gates)
        if kind == "vector":
            in_specs.append(chunk_spec(dk))
        else:
            in_specs.append(pl.BlockSpec((1, chunk), lambda i, j: (i, j)))
        operands.append(gf)
    if has_b:
        operands.append(_flatten_bh(beta))
        in_specs.append(pl.BlockSpec((1, chunk), lambda i, j: (i, j)))
    operands.append(m0f)
    in_specs.append(state_spec)

    def body(*refs):
        o_ref, m_ref = refs[-2], refs[-1]
        it = iter(refs[:-2])
        q_ref, k_ref, v_ref = next(it), next(it), next(it)
        g_ref = next(it) if has_g else None
        b_ref = next(it) if has_b else None
        m0_ref = next(it)
        _kernel_body(kind, q_ref, k_ref, v_ref, g_ref, b_ref, m0_ref,
                     o_ref, m_ref)

    o, m_final = pl.pallas_call(
        body,
        grid=(bh, nc),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)

    return o.reshape(b, h, n, dv), m_final.reshape(b, h, dk, dv)


# ---------------------------------------------------------------------------
# Differentiable wrapper.  pallas_call has no autodiff rule, so training
# uses jax.custom_vjp: the *forward* is the Pallas kernel (the hot path that
# also serves decode/prefill), and the *backward* recomputes the forward
# through the chunkwise-jnp formulation and differentiates it -- exact
# gradients with linear memory, i.e. kernel-level activation recomputation
# (the same trade Megatron's selective recompute makes).
# ---------------------------------------------------------------------------


def _chunked_apply(kind, chunk, q, k, v, gates, beta, m0):
    if kind == "none":
        return chunked.bla(q, k, v, chunk, m0)
    if kind == "scalar":
        return chunked.simple_decay(q, k, v, gates, chunk, m0)
    if kind == "vector":
        return chunked.vector_decay(q, k, v, gates, chunk, m0)
    if kind == "beta":
        return chunked.delta_rule(q, k, v, beta, chunk, m0)
    if kind == "scalar+beta":
        return chunked.gated_delta_rule(q, k, v, gates, beta, chunk, m0)
    raise ValueError(kind)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def lsm_ad(kind, chunk, q, k, v, gates, beta, m0):
    return _chunked_apply(kind, chunk, q, k, v, gates, beta, m0)


def _lsm_ad_fwd(kind, chunk, q, k, v, gates, beta, m0):
    out = lsm_pallas(kind, q, k, v, gates=gates, beta=beta, chunk=chunk,
                     m0=m0)
    return out, (q, k, v, gates, beta, m0)


def _lsm_ad_bwd(kind, chunk, res, ct):
    _, vjp = jax.vjp(
        lambda *a: _chunked_apply(kind, chunk, *a), *res)
    return vjp(ct)


lsm_ad.defvjp(_lsm_ad_fwd, _lsm_ad_bwd)


# Named wrappers matching ref.ORACLES / chunked.CHUNKED signatures.

def bla(q, k, v, chunk=64, m0=None, interpret=True):
    return lsm_pallas("none", q, k, v, chunk=chunk, m0=m0, interpret=interpret)


def simple_decay(q, k, v, alpha, chunk=64, m0=None, interpret=True):
    return lsm_pallas("scalar", q, k, v, gates=alpha, chunk=chunk, m0=m0,
                      interpret=interpret)


def vector_decay(q, k, v, alpha, chunk=64, m0=None, interpret=True):
    return lsm_pallas("vector", q, k, v, gates=alpha, chunk=chunk, m0=m0,
                      interpret=interpret)


def hgrn2(q, k, v, alpha, chunk=64, m0=None, interpret=True):
    return lsm_pallas("vector", q, 1.0 - alpha, v, gates=alpha, chunk=chunk,
                      m0=m0, interpret=interpret)


def delta_rule(q, k, v, beta, chunk=64, m0=None, interpret=True):
    return lsm_pallas("beta", q, k, v, beta=beta, chunk=chunk, m0=m0,
                      interpret=interpret)


def gated_delta_rule(q, k, v, alpha, beta, chunk=64, m0=None, interpret=True):
    return lsm_pallas("scalar+beta", q, k, v, gates=alpha, beta=beta,
                      chunk=chunk, m0=m0, interpret=interpret)


PALLAS = {
    "bla": (bla, "none"),
    "retention": (simple_decay, "scalar"),
    "lightning": (simple_decay, "scalar"),
    "mamba2": (simple_decay, "scalar"),
    "gla": (vector_decay, "vector"),
    "rwkv6": (vector_decay, "vector"),
    "hgrn2": (hgrn2, "vector"),
    "deltanet": (delta_rule, "beta"),
    "gated_deltanet": (gated_delta_rule, "scalar+beta"),
}
