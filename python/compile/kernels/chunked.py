# Chunkwise-parallel formulations of the unified LSM recurrence.
#
# Every LSM instance in paper Table 1 that we ship factors into
#
#     o_chunk = o_intra (parallel, within-chunk, matmul-shaped)
#             + o_inter (q_chunk applied to the carried state M)
#     M_new   = decay(chunk) <> M + contribution(chunk)
#
# which is exactly the structure LASP (paper App. A.3, Alg. 2) exploits for
# sequence parallelism: `chunk_state_*` computes the per-chunk state
# contribution that is AllGather-ed across SP ranks, and `chunk_output_*`
# combines the local intra-chunk output with the prefix state.
#
# These are pure-jnp; pallas_lsm.py wraps the same single-chunk math in a
# Pallas grid, and tests/test_kernels.py checks both against ref.py.
#
# Numerical-stability policy (documented in DESIGN.md): vector-gated
# instances (GLA / HGRN2 / RWKV6) compute the intra-chunk term in the
# factored form (Q*exp(G)) @ (K*exp(-G))^T, which requires the per-token
# log-decay to be bounded below.  The model layer (lsm.py) parameterizes
# log(alpha) = -GATE_CAP * sigmoid(z) with GATE_CAP = 0.25, so over a chunk
# of 64 tokens exp(-G) <= e^16 -- comfortably inside f32.  Scalar-decay
# instances use the pairwise-ratio form exp(G_i - G_j) (i >= j), which is
# <= 1 for any decay strength, so they need no bound.

import math
from functools import partial

import jax
import jax.numpy as jnp

# Upper bound on the per-token *negative* log-decay for vector gates.
GATE_CAP = 0.25


def causal_mask(c, dtype=jnp.float32, inclusive=True):
    """(c, c) lower-triangular mask; inclusive keeps the diagonal."""
    m = jnp.tril(jnp.ones((c, c), dtype=bool), 0 if inclusive else -1)
    return m.astype(dtype)


def unit_lower_inv(a):
    """Invert (I + A) for strictly-lower-triangular A (..., C, C).

    A is nilpotent (A^C = 0) so (I+A)^{-1} = sum_k (-A)^k, computed with
    ceil(log2(C)) matmuls via (I+B)(I+B^2)(I+B^4)... , B = -A.  This is
    matmul-only (MXU-friendly on TPU) -- no triangular solve needed.
    """
    c = a.shape[-1]
    eye = jnp.eye(c, dtype=a.dtype)
    b = -a
    inv = eye + b
    p = b
    for _ in range(max(0, math.ceil(math.log2(max(c, 2))) - 1)):
        p = p @ p
        inv = inv + inv @ p
    return inv


# ---------------------------------------------------------------------------
# Single-chunk primitives.  All take per-chunk tensors:
#   q, k : (..., C, Dk)   v : (..., C, Dv)   m : (..., Dk, Dv)
#   scalar gate log-decays g : (..., C)   vector g : (..., C, Dk)
# and return (o, m_new).  `...` is any leading batch shape (B, H) or ().
# ---------------------------------------------------------------------------


def chunk_bla(q, k, v, m):
    """BLA:  no decay."""
    mask = causal_mask(q.shape[-2], q.dtype)
    attn = (q @ jnp.swapaxes(k, -1, -2)) * mask
    o = attn @ v + q @ m
    m_new = m + jnp.swapaxes(k, -1, -2) @ v
    return o, m_new


def chunk_scalar_decay(q, k, v, g, m):
    """Scalar decay; g = log(alpha) per token, shape (..., C), g <= 0.

    Intra term uses the pairwise-ratio form exp(G_i - G_j) <= 1 (i >= j),
    stable for arbitrarily strong decay.
    """
    gc = jnp.cumsum(g, axis=-1)                      # inclusive cumsum
    ratio = gc[..., :, None] - gc[..., None, :]      # G_i - G_j
    mask = causal_mask(q.shape[-2], q.dtype)
    # mask *before* exp: for i < j the ratio is positive and can overflow
    # under strong decay (exp(inf) * 0 = NaN); clamp those lanes to -inf.
    d = jnp.exp(jnp.where(mask > 0, ratio, -jnp.inf))
    attn = (q @ jnp.swapaxes(k, -1, -2)) * d
    o = attn @ v + jnp.exp(gc)[..., :, None] * (q @ m)
    g_last = gc[..., -1:]
    k_scaled = k * jnp.exp(g_last - gc)[..., :, None]
    m_new = jnp.exp(g_last)[..., :, None] * m + jnp.swapaxes(k_scaled, -1, -2) @ v
    return o, m_new


def chunk_vector_decay(q, k, v, g, m):
    """Vector decay; g = log(alpha) per token per dim, (..., C, Dk), g <= 0.

    Requires g >= -GATE_CAP per token (see module docstring).
    """
    gc = jnp.cumsum(g, axis=-2)                      # (..., C, Dk)
    q_s = q * jnp.exp(gc)
    k_s = k * jnp.exp(-gc)
    mask = causal_mask(q.shape[-2], q.dtype)
    attn = (q_s @ jnp.swapaxes(k_s, -1, -2)) * mask
    o = attn @ v + q_s @ m
    g_last = gc[..., -1:, :]                         # (..., 1, Dk)
    k_rest = k * jnp.exp(g_last - gc)
    m_new = jnp.exp(g_last[..., 0, :, None]) * m + jnp.swapaxes(k_rest, -1, -2) @ v
    return o, m_new


def chunk_delta(q, k, v, beta, m):
    """DeltaNet (WY representation, Yang et al. 2024c).

    With w_t = beta_t (v_t - k_t M_{t-1}) the in-chunk recurrence becomes
    (I + A) W = diag(beta) (V - K M),  A = strict_tril(diag(beta) K K^T),
    so W is recovered with one nilpotent inverse; then
    M_new = M + K^T W  and  o_t = q_t M + sum_{j<=t} (q_t . k_j) w_j.
    """
    c = q.shape[-2]
    kk = k @ jnp.swapaxes(k, -1, -2)                       # (..., C, C)
    a = (beta[..., :, None] * kk) * causal_mask(c, q.dtype, inclusive=False)
    rhs = beta[..., :, None] * (v - k @ m)
    w = unit_lower_inv(a) @ rhs                            # (..., C, Dv)
    m_new = m + jnp.swapaxes(k, -1, -2) @ w
    attn = (q @ jnp.swapaxes(k, -1, -2)) * causal_mask(c, q.dtype)
    o = q @ m + attn @ w
    return o, m_new


def chunk_gated_delta(q, k, v, g, beta, m):
    """Gated DeltaNet: scalar decay g = log(alpha) composed with delta rule.

    M_t = a_t (I - b_t k_t^T k_t) M_{t-1} + b_t k_t^T v_t.  Absorbing the
    decay into rescaled keys (k_t' = k_t * exp(G_t)) reduces to the plain
    delta chunk on rescaled inputs; we use the direct stable form: carry the
    decay inside the within-chunk solve by rescaling K rows by exp(-(G_t -
    G_j)) pairwise.  Implementation below follows the same WY derivation
    with w_t = b_t (v_t - k_t D_t M ...) adapted for the scalar gate.
    """
    c = q.shape[-2]
    gc = jnp.cumsum(g, axis=-1)                            # (..., C)
    # Pairwise decays r_{tj} = exp(G_t - G_j) for t >= j ( <= 1, stable);
    # mask before exp so the i < j lanes cannot overflow to inf.
    incl = causal_mask(c, q.dtype)
    diff = gc[..., :, None] - gc[..., None, :]
    ratio = jnp.exp(jnp.where(incl > 0, diff, -jnp.inf))
    kk = k @ jnp.swapaxes(k, -1, -2)
    a = (beta[..., :, None] * kk * ratio) * causal_mask(c, q.dtype, inclusive=False)
    rhs = beta[..., :, None] * (v - jnp.exp(gc)[..., :, None] * (k @ m))
    w = unit_lower_inv(a) @ rhs
    # o_t = exp(G_t) q_t M + sum_{j<=t} (q_t.k_j) exp(G_t - G_j) w_j
    attn = (q @ jnp.swapaxes(k, -1, -2)) * ratio * causal_mask(c, q.dtype)
    o = jnp.exp(gc)[..., :, None] * (q @ m) + attn @ w
    g_last = gc[..., -1:]
    k_rest = k * jnp.exp(g_last - gc)[..., :, None]
    m_new = jnp.exp(g_last)[..., :, None] * m + jnp.swapaxes(k_rest, -1, -2) @ w
    return o, m_new


# ---------------------------------------------------------------------------
# Full-sequence chunked runners: scan the single-chunk primitive over the
# sequence.  q,k:(B,H,N,Dk) v:(B,H,N,Dv); N must be divisible by chunk.
# ---------------------------------------------------------------------------


def _to_chunks(t, c):
    b, h, n = t.shape[:3]
    return t.reshape(b, h, n // c, c, *t.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)


def _from_chunks(t):
    # (NC, B, H, C, ...) -> (B, H, N, ...)
    nc, b, h, c = t.shape[:4]
    return t.swapaxes(1, 2).swapaxes(0, 2).reshape(b, h, nc * c, *t.shape[4:])


def _run(chunk_fn, q, k, v, extras, chunk, m0):
    b, h, n, dk = k.shape
    dv = v.shape[-1]
    assert n % chunk == 0, f"N={n} not divisible by chunk={chunk}"
    if m0 is None:
        m0 = jnp.zeros((b, h, dk, dv), dtype=jnp.float32)
    xs = tuple(_to_chunks(t, chunk) for t in (q, k, v) + tuple(extras))

    def body(m, ts):
        o, m_new = chunk_fn(*ts, m)
        return m_new, o

    m_final, o = jax.lax.scan(body, m0, xs)
    return _from_chunks(o), m_final


def bla(q, k, v, chunk=64, m0=None):
    return _run(chunk_bla, q, k, v, (), chunk, m0)


def simple_decay(q, k, v, alpha, chunk=64, m0=None):
    g = jnp.log(alpha)
    return _run(chunk_scalar_decay, q, k, v, (g,), chunk, m0)


def vector_decay(q, k, v, alpha, chunk=64, m0=None):
    g = jnp.log(alpha)
    return _run(chunk_vector_decay, q, k, v, (g,), chunk, m0)


def hgrn2(q, k, v, alpha, chunk=64, m0=None):
    return vector_decay(q, 1.0 - alpha, v, alpha, chunk, m0)


def delta_rule(q, k, v, beta, chunk=64, m0=None):
    return _run(chunk_delta, q, k, v, (beta,), chunk, m0)


def gated_delta_rule(q, k, v, alpha, beta, chunk=64, m0=None):
    g = jnp.log(alpha)
    return _run(
        lambda qq, kk, vv, gg, bb, m: chunk_gated_delta(qq, kk, vv, gg, bb, m),
        q, k, v, (g, beta), chunk, m0,
    )


CHUNKED = {
    "bla": (bla, "none"),
    "retention": (simple_decay, "scalar"),
    "lightning": (simple_decay, "scalar"),
    "mamba2": (simple_decay, "scalar"),
    "gla": (vector_decay, "vector"),
    "rwkv6": (vector_decay, "vector"),
    "hgrn2": (hgrn2, "vector"),
    "deltanet": (delta_rule, "beta"),
    "gated_deltanet": (gated_delta_rule, "scalar+beta"),
}


# ---------------------------------------------------------------------------
# LASP sequence-parallel primitives (paper App. A.3).
#
# chunk_state: the per-rank "M_t = K_t^T V_t (with decay)" that Alg. 1/2
#   line 6 computes before the AllGather.  Returns (m_contrib, log_decay)
#   where the prefix state folds as  M_prefix' = exp(ld) <> M_prefix + mc.
# chunk_output: Alg. 2 lines 8-11 -- intra output + q applied to the
#   gathered prefix state.
# These are what aot.py lowers as `sp_state_*` / `sp_output_*` artifacts;
# the Rust coordinator performs the AllGather / prefix-scan between them.
# ---------------------------------------------------------------------------


def sp_chunk_state(kind, k, v, gates):
    """Per-rank state contribution. k:(B,H,C,Dk) v:(B,H,C,Dv).
    Returns (m_contrib:(B,H,Dk,Dv), log_decay:(B,H,Dk)) -- log_decay is the
    total per-dim log decay across this chunk (zeros when the instance has
    no decay), so ranks fold prefix states as
        M' = exp(log_decay)[:, None] * M_prev + m_contrib.
    """
    b, h, c, dk = k.shape
    if kind == "none":
        mc = jnp.swapaxes(k, -1, -2) @ v
        ld = jnp.zeros((b, h, dk), jnp.float32)
    elif kind == "scalar":
        g = jnp.log(gates)                            # (B,H,C)
        gc = jnp.cumsum(g, axis=-1)
        g_last = gc[..., -1:]
        k_s = k * jnp.exp(g_last - gc)[..., :, None]
        mc = jnp.swapaxes(k_s, -1, -2) @ v
        ld = jnp.broadcast_to(g_last, (b, h, dk)).astype(jnp.float32)
    elif kind == "vector":
        g = jnp.log(gates)                            # (B,H,C,Dk)
        gc = jnp.cumsum(g, axis=-2)
        g_last = gc[..., -1:, :]
        k_s = k * jnp.exp(g_last - gc)
        mc = jnp.swapaxes(k_s, -1, -2) @ v
        ld = g_last[..., 0, :]
    else:
        raise ValueError(f"sp_chunk_state: unsupported kind {kind!r}")
    return mc, ld


def sp_chunk_output(kind, q, k, v, gates, m_prefix):
    """Per-rank output given the gathered prefix state (Alg. 2 lines 8-11)."""
    if kind == "none":
        o, _ = chunk_bla(q, k, v, m_prefix)
    elif kind == "scalar":
        o, _ = chunk_scalar_decay(q, k, v, jnp.log(gates), m_prefix)
    elif kind == "vector":
        o, _ = chunk_vector_decay(q, k, v, jnp.log(gates), m_prefix)
    else:
        raise ValueError(f"sp_chunk_output: unsupported kind {kind!r}")
    return o
