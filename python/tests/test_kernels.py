# L1 correctness: chunked formulations and Pallas kernels vs the
# sequential oracles in ref.py -- the CORE correctness signal of the repo.
#
# hypothesis sweeps shapes / dtypes / chunk sizes / gate strengths; each
# instance is checked in three forms (ref == chunked == pallas) plus the
# nonzero-initial-state path used by LASP and decode.

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st

from compile.kernels import attn, chunked, pallas_lsm, ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([(1, 1, 64, 8, 8), (2, 2, 128, 16, 32),
                        (1, 3, 96, 24, 16)])
CHUNKS = st.sampled_from([16, 32, 64])
SEEDS = st.integers(min_value=0, max_value=2 ** 31 - 1)


def make_inputs(seed, dims, scale=0.5):
    b, h, n, dk, dv = dims
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, n, dk)), jnp.float32) * scale
    k = jnp.asarray(rng.normal(size=(b, h, n, dk)), jnp.float32) * scale
    v = jnp.asarray(rng.normal(size=(b, h, n, dv)), jnp.float32) * scale
    a_s = jnp.asarray(rng.uniform(0.7, 1.0, size=(b, h, n)), jnp.float32)
    a_v = jnp.asarray(
        np.exp(-chunked.GATE_CAP * rng.uniform(0, 1, size=(b, h, n, dk))),
        jnp.float32)
    beta = jnp.asarray(rng.uniform(0.05, 0.95, size=(b, h, n)), jnp.float32)
    kn = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    m0 = jnp.asarray(rng.normal(size=(b, h, dk, dv)), jnp.float32) * scale
    return q, k, v, a_s, a_v, beta, kn, m0


def assert_close(a, b, atol=5e-4, rtol=5e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol)


CASES = [
    # (name, ref_fn(args), chunked_fn, pallas_fn, which gates)
    ("bla", "none"),
    ("retention", "scalar"),
    ("gla", "vector"),
    ("hgrn2", "hgrn2"),
    ("deltanet", "beta"),
    ("gated_deltanet", "scalar+beta"),
]


def run_all(name, kind, inputs, chunk, m0=None):
    q, k, v, a_s, a_v, beta, kn, _ = inputs
    if kind == "none":
        r = ref.bla(q, k, v, m0)
        c = chunked.bla(q, k, v, chunk, m0)
        p = pallas_lsm.bla(q, k, v, chunk, m0)
    elif kind == "scalar":
        r = ref.simple_decay(q, k, v, a_s, m0)
        c = chunked.simple_decay(q, k, v, a_s, chunk, m0)
        p = pallas_lsm.simple_decay(q, k, v, a_s, chunk, m0)
    elif kind == "vector":
        r = ref.vector_decay(q, k, v, a_v, m0)
        c = chunked.vector_decay(q, k, v, a_v, chunk, m0)
        p = pallas_lsm.vector_decay(q, k, v, a_v, chunk, m0)
    elif kind == "hgrn2":
        r = ref.hgrn2(q, k, v, a_v, m0)
        c = chunked.hgrn2(q, k, v, a_v, chunk, m0)
        p = pallas_lsm.hgrn2(q, k, v, a_v, chunk, m0)
    elif kind == "beta":
        r = ref.delta_rule(q, kn, v, beta, m0)
        c = chunked.delta_rule(q, kn, v, beta, chunk, m0)
        p = pallas_lsm.delta_rule(q, kn, v, beta, chunk, m0)
    elif kind == "scalar+beta":
        r = ref.gated_delta_rule(q, kn, v, a_s, beta, m0)
        c = chunked.gated_delta_rule(q, kn, v, a_s, beta, chunk, m0)
        p = pallas_lsm.gated_delta_rule(q, kn, v, a_s, beta, chunk, m0)
    return r, c, p


@pytest.mark.parametrize("name,kind", CASES)
@settings(max_examples=8, deadline=None)
@given(seed=SEEDS, dims=DIMS, chunk=CHUNKS)
def test_chunked_and_pallas_match_ref(name, kind, seed, dims, chunk):
    assume(dims[2] % chunk == 0)
    inputs = make_inputs(seed, dims)
    (ro, rm), (co, cm), (po, pm) = run_all(name, kind, inputs, chunk)
    assert_close(ro, co)
    assert_close(rm, cm)
    assert_close(ro, po)
    assert_close(rm, pm)


@pytest.mark.parametrize("name,kind", CASES)
def test_nonzero_initial_state(name, kind):
    inputs = make_inputs(7, (2, 2, 128, 16, 32))
    m0 = inputs[-1]
    (ro, rm), (co, cm), (po, pm) = run_all(name, kind, inputs, 32, m0=m0)
    assert_close(ro, co)
    assert_close(ro, po)
    assert_close(rm, pm)


@settings(max_examples=6, deadline=None)
@given(seed=SEEDS, dims=DIMS, chunk=CHUNKS)
def test_attention_kernel_matches_ref(seed, dims, chunk):
    assume(dims[2] % min(chunk, dims[2]) == 0)
    q, k, v, *_ = make_inputs(seed, dims)
    r = ref.softmax_attention(q, k, v)
    p = attn.softmax_attention(q, k, v, chunk=min(chunk, q.shape[2]))
    assert_close(r, p, atol=1e-4, rtol=1e-4)


def test_strong_scalar_decay_is_stable():
    """Scalar-decay pairwise-ratio form must survive near-zero decay."""
    q, k, v, *_ = make_inputs(3, (1, 1, 128, 16, 16))
    a = jnp.full((1, 1, 128), 0.01, jnp.float32)     # brutal forgetting
    ro, rm = ref.simple_decay(q, k, v, a)
    po, pm = pallas_lsm.simple_decay(q, k, v, a, 32)
    assert bool(jnp.all(jnp.isfinite(po)))
    assert_close(ro, po)


def test_vector_gate_cap_boundary():
    """Vector gates exactly at the stability bound alpha=exp(-GATE_CAP)."""
    q, k, v, *_ = make_inputs(4, (1, 2, 128, 16, 16))
    a = jnp.full((1, 2, 128, 16), float(np.exp(-chunked.GATE_CAP)))
    ro, _ = ref.vector_decay(q, k, v, a)
    po, _ = pallas_lsm.vector_decay(q, k, v, a, 64)
    assert bool(jnp.all(jnp.isfinite(po)))
    assert_close(ro, po)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep_bla(dtype):
    q, k, v, *_ = make_inputs(5, (1, 2, 64, 16, 16))
    q, k, v = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    ro, _ = ref.bla(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32))
    po, _ = pallas_lsm.bla(q, k, v, 32)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    assert_close(ro, po.astype(jnp.float32), atol=tol, rtol=tol)


def test_unit_lower_inv_exact():
    rng = np.random.default_rng(0)
    for c in (4, 16, 33, 64):
        # entry scale matches the delta kernel's A = beta * K K^T with
        # L2-normalized k and beta < 1 (unscaled normals make ||B^k||
        # blow past f32 long before nilpotency cancels it).
        a = np.tril(rng.normal(size=(c, c)), -1).astype(np.float32)
        a *= 0.5 / np.sqrt(c)
        inv = np.asarray(chunked.unit_lower_inv(jnp.asarray(a)))
        np.testing.assert_allclose(inv @ (np.eye(c) + a), np.eye(c),
                                   atol=1e-4)


def test_gradients_flow_through_pallas_ad():
    """lsm_ad: Pallas forward + recompute-chunked backward must give the
    same grads as pure-jnp chunked end to end."""
    q, k, v, a_s, a_v, beta, kn, m0 = make_inputs(9, (1, 2, 64, 8, 8))

    def loss_ad(q_, k_, v_, g_):
        o, m = pallas_lsm.lsm_ad("vector", 32, q_, k_, v_, g_, None, None)
        return jnp.sum(o ** 2) + jnp.sum(m ** 2)

    def loss_ref(q_, k_, v_, g_):
        o, m = chunked.vector_decay(q_, k_, v_, g_, 32)
        return jnp.sum(o ** 2) + jnp.sum(m ** 2)

    g1 = jax.grad(loss_ad, argnums=(0, 1, 2, 3))(q, k, v, a_v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, a_v)
    for a, b in zip(g1, g2):
        assert_close(a, b, atol=1e-3, rtol=1e-3)


def test_sp_decomposition_equals_serial():
    """LASP (paper Alg. 2): chunk outputs + prefix-folded states == serial
    execution, for every gate kind, across SP sizes."""
    q, k, v, a_s, a_v, beta, kn, _ = make_inputs(11, (2, 2, 128, 16, 32))
    for kind, gates, kk in (("none", None, k), ("scalar", a_s, k),
                            ("vector", a_v, k)):
        if kind == "none":
            o_ref, m_ref = ref.bla(q, kk, v)
        elif kind == "scalar":
            o_ref, m_ref = ref.simple_decay(q, kk, v, gates)
        else:
            o_ref, m_ref = ref.vector_decay(q, kk, v, gates)
        for t in (2, 4):
            nh = q.shape[2] // t
            m_prefix = jnp.zeros_like(m_ref)
            outs = []
            for r in range(t):
                sl = slice(r * nh, (r + 1) * nh)
                gsl = None if gates is None else gates[:, :, sl]
                o = chunked.sp_chunk_output(kind, q[:, :, sl], kk[:, :, sl],
                                            v[:, :, sl], gsl, m_prefix)
                mc, ld = chunked.sp_chunk_state(kind, kk[:, :, sl],
                                                v[:, :, sl], gsl)
                m_prefix = jnp.exp(ld)[..., None] * m_prefix + mc
                outs.append(o)
            assert_close(o_ref, jnp.concatenate(outs, axis=2))
            assert_close(m_ref, m_prefix)
