# L2 correctness: model shapes, gradients, MoE strategies, hybrid stacks,
# decode-vs-forward consistency, pipeline-stage composition.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, moe, stages
from compile.config import PRESETS, ModelConfig, layout

jax.config.update("jax_platform_name", "cpu")

CFG = PRESETS["tiny"]


def data(cfg, b=2, n=128, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, n)), jnp.int32)
    tgts = jnp.asarray(rng.integers(0, cfg.vocab, (b, n)), jnp.int32)
    return toks, tgts


@pytest.mark.parametrize("inst", ["bla", "retention", "gla", "deltanet",
                                  "mamba2", "hgrn2", "rwkv6"])
def test_forward_shapes_every_instance(inst):
    cfg = CFG.with_(lsm=inst)
    p = model.init_params(cfg)
    toks, _ = data(cfg)
    logits, aux = model.forward(cfg, p, toks)
    assert logits.shape == (2, 128, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert float(aux) > 0.0


def test_forward_hybrid_and_attn():
    for lay in ("NN", "LN"):
        cfg = CFG.with_(layout=lay)
        p = model.init_params(cfg)
        toks, _ = data(cfg)
        logits, _ = model.forward(cfg, p, toks)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_grads_finite_and_loss_decreases():
    cfg = CFG
    p = model.init_params(cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    toks, tgts = data(cfg)
    losses = []
    step_fn = jax.jit(lambda p_, m_, v_, s: model.train_step(
        cfg, p_, m_, v_, s, jnp.float32(1e-3), toks, tgts))
    for s in range(5):
        loss, ce, p, m, v = step_fn(p, m, v, jnp.int32(s + 1))
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


def test_loss_mask_ignores_negative_targets():
    cfg = CFG
    p = model.init_params(cfg)
    toks, tgts = data(cfg)
    full, _ = model.loss_fn(cfg, p, toks, tgts)
    # mask the second half; loss must equal loss computed on first half only
    tgts_masked = tgts.at[:, 64:].set(-1)
    masked, _ = model.loss_fn(cfg, p, toks, tgts_masked)
    assert np.isfinite(float(masked))
    assert abs(float(masked) - float(full)) > 1e-6  # actually different


def test_moe_strategies_agree():
    """dense / loop / grouped agree on kept tokens; with a generous
    capacity factor nothing is dropped and all three match exactly."""
    cfg = CFG.with_(capacity_factor=8.0)   # no drops
    key = jax.random.PRNGKey(0)
    p = moe.init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    yd, auxd = moe.moe_layer(cfg, p, x, "dense")
    yl, auxl = moe.moe_layer(cfg, p, x, "loop")
    yg, auxg = moe.moe_layer(cfg, p, x, "grouped")
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg), atol=1e-4)
    np.testing.assert_allclose(np.asarray(yl), np.asarray(yg), atol=1e-5)
    assert abs(float(auxd) - float(auxg)) < 1e-6


def test_moe_capacity_drops_tokens():
    cfg = CFG.with_(capacity_factor=0.25)  # force drops
    key = jax.random.PRNGKey(0)
    p = moe.init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    yd, _ = moe.moe_layer(cfg, p, x, "dense")
    yg, _ = moe.moe_layer(cfg, p, x, "grouped")
    # dropped tokens make outputs differ
    assert float(jnp.max(jnp.abs(yd - yg))) > 1e-4
    assert bool(jnp.all(jnp.isfinite(yg)))


def test_router_probs_and_aux():
    cfg = CFG
    p = moe.init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, cfg.d_model))
    gates, idx, aux = moe.route(cfg, p, x)
    assert gates.shape == (128, cfg.top_k)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                               atol=1e-5)
    assert int(jnp.min(idx)) >= 0 and int(jnp.max(idx)) < cfg.n_experts
    assert float(aux) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz at balance


def test_decode_matches_forward():
    """Stepping decode over a sequence must reproduce the training-path
    forward logits (pure model).  This is the paper's claim that linear
    decoding with constant state is exact, not an approximation."""
    cfg = CFG.with_(lsm="gla", n_layers=2, layout="LL", chunk=16)
    p = model.init_params(cfg)
    toks, _ = data(cfg, b=2, n=32)
    logits_fwd, _ = model.forward(cfg, p, toks, backend="chunked")
    states = model.init_decode_state(cfg, 2)
    outs = []
    for t in range(32):
        lg, states = model.decode_step(cfg, p, states, toks[:, t],
                                       jnp.int32(t))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_fwd),
                               np.asarray(logits_dec), atol=2e-3, rtol=2e-3)


def test_decode_matches_forward_hybrid():
    cfg = CFG.with_(lsm="gla", n_layers=2, layout="LN", chunk=16)
    p = model.init_params(cfg)
    toks, _ = data(cfg, b=1, n=32)
    logits_fwd, _ = model.forward(cfg, p, toks, backend="chunked")
    states = model.init_decode_state(cfg, 1, max_n=32)
    outs = []
    for t in range(32):
        lg, states = model.decode_step(cfg, p, states, toks[:, t],
                                       jnp.int32(t))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_fwd),
                               np.asarray(logits_dec), atol=2e-3, rtol=2e-3)


def test_pipeline_stage_composition_matches_monolith():
    """embed/block/head fwd+bwd pieces composed in sequence must reproduce
    the monolithic fwd_bwd -- this is the invariant the Rust pipeline
    scheduler relies on."""
    cfg = CFG.with_(lsm="gla", n_layers=2, layout="LL")
    p = model.init_params(cfg)
    toks, tgts = data(cfg, b=1, n=64)

    loss_mono, ce_mono, grads_mono = model.fwd_bwd(cfg, p, toks, tgts)

    # forward through stages
    x0 = stages.embed_fwd(p["embed"], toks)
    x1, aux1 = stages.block_fwd(cfg, "L", p["layers"][0], x0)
    x2, aux2 = stages.block_fwd(cfg, "L", p["layers"][1], x1)
    gfn, gemb_head, gx2, ce = stages.head_bwd(
        cfg, p["final_norm"], p["embed"], x2, tgts)
    np.testing.assert_allclose(float(ce), float(ce_mono), atol=1e-5)

    g1, gx1 = stages.block_bwd(cfg, "L", p["layers"][1], x1, gx2)
    g0, gx0 = stages.block_bwd(cfg, "L", p["layers"][0], x0, gx1)
    gemb_tok = stages.embed_bwd(toks, gx0, cfg.vocab)
    gemb = gemb_head + gemb_tok

    np.testing.assert_allclose(np.asarray(grads_mono["final_norm"]),
                               np.asarray(gfn), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(grads_mono["embed"]),
                               np.asarray(gemb), atol=2e-4, rtol=1e-3)
    for got, want in ((g1, grads_mono["layers"][1]),
                      (g0, grads_mono["layers"][0])):
        for leaf_g, leaf_w in zip(jax.tree_util.tree_leaves(got),
                                  jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(leaf_g),
                                       np.asarray(leaf_w),
                                       atol=3e-4, rtol=2e-3)


def test_adam_matches_reference():
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    m = jnp.zeros((64,)); v = jnp.zeros((64,))
    p2, m2, v2 = model.adam_update(p, g, m, v, jnp.int32(1),
                                   jnp.float32(1e-2))
    # reference numpy adam
    b1, b2, eps = model.ADAM_B1, model.ADAM_B2, model.ADAM_EPS
    mr = (1 - b1) * np.asarray(g)
    vr = (1 - b2) * np.asarray(g) ** 2
    pr = np.asarray(p) - 1e-2 * (mr / (1 - b1)) / (np.sqrt(vr / (1 - b2)) + eps)
    np.testing.assert_allclose(np.asarray(p2), pr, atol=1e-6)


def test_param_count_sparse_vs_activated():
    total, act = model.param_count(PRESETS["tiny"])
    assert act < total
    # activated must shrink as top_k/n_experts ratio shrinks
    cfg2 = PRESETS["tiny"].with_(n_experts=8, top_k=1)
    t2, a2 = model.param_count(cfg2)
    assert a2 / t2 < act / total


def test_layout_helper():
    assert layout(12, False) == "L" * 12
    assert layout(12, True) == "LLLNLLLNLLLN"   # paper §3.3 pattern
    assert layout(16, True).count("N") == 4
