//! Quickstart: load the Linear-MoE artifacts, initialize a tiny GLA
//! Linear-MoE model, and run a few training steps — the minimal end-to-end
//! path through all three layers (Pallas kernel → JAX model → Rust
//! coordinator via PJRT).
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use linear_moe::rng::Rng;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::{Bundle, Tensor};

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("platform: {}", rt.platform());

    let tag = "tiny_gla";
    let var = rt.manifest.variant(tag)?.clone();
    println!(
        "variant {tag}: {} layers ({}), {} experts (top-{}), {} params ({} activated)",
        var.config.n_layers, var.config.layout, var.config.n_experts,
        var.config.top_k, var.params_total, var.params_activated
    );

    // Initialize parameters by running the init artifact (seed 0).
    let params = rt.init_params(tag, 0)?;
    let m = params.zeros_like();
    let v = params.zeros_like();

    // Synthetic batch: random tokens with a strong bigram structure so the
    // model has something learnable even in a demo.
    let (b, n) = (2usize, 128usize);
    let step_exe = rt.load(&format!("train_step_{tag}_b{b}n{n}"))?;
    let mut rng = Rng::new(7);
    let vocab = var.config.vocab;
    let mut toks = vec![0i32; b * n];
    for row in toks.chunks_mut(n) {
        row[0] = rng.below(vocab) as i32;
        for i in 1..n {
            // bigram: next = (prev * 31 + small noise) mod vocab
            let noise = rng.below(4) as i32;
            row[i] = (row[i - 1] * 31 + noise).rem_euclid(vocab as i32);
        }
    }
    let tokens = Tensor::i32(&[b, n], toks.clone());
    // next-token targets: shift left, mask the last position
    let mut tg = vec![0i32; b * n];
    for (r, row) in toks.chunks(n).enumerate() {
        for i in 0..n - 1 {
            tg[r * n + i] = row[i + 1];
        }
        tg[r * n + n - 1] = -1;
    }
    let targets = Tensor::i32(&[b, n], tg);

    let (mut params, mut m, mut v) = (params, m, v);
    let lr = Tensor::scalar_f32(3e-3);
    println!("step |   loss  |   ce");
    for step in 1..=10 {
        let step_t = Tensor::scalar_i32(step);
        let out = step_exe.run_bundled(&[&params, &m, &v],
                                       &[&step_t, &lr, &tokens, &targets])?;
        let loss = out[0].item_f32()?;
        let ce = out[1].item_f32()?;
        let np = params.tensors.len();
        params = Bundle::new(out[2..2 + np].to_vec());
        m = Bundle::new(out[2 + np..2 + 2 * np].to_vec());
        v = Bundle::new(out[2 + 2 * np..2 + 3 * np].to_vec());
        println!("{step:4} | {loss:7.4} | {ce:7.4}");
    }
    println!("quickstart OK");
    Ok(())
}
