//! END-TO-END DRIVER (paper Fig. 6 / Fig. 7): pretrain Linear-MoE model
//! instances from scratch on the synthetic corpus and record loss curves.
//!
//! Paper: A0.3B-2B (15B tokens) / A1B-7B (100B tokens) on SlimPajama,
//! pure ("LLLL...") and hybrid ("LLLN...") stacks vs the attention
//! Baseline.  Here: the `small` preset (~13M params, ~7M activated) on the
//! Zipf-Markov corpus, a few hundred steps on CPU-PJRT -- the claim under
//! test is *relative*: pure Linear-MoE converges competitively with the
//! Baseline and hybrids are at least as good.
//!
//!   cargo run --release --example train_loss_curves -- \
//!       [--steps 300] [--tags small_gla,small_glah,small_attn] [--out results/fig6.csv]

use std::sync::Arc;

use linear_moe::coordinator::ddp::{run_fused, BatchFn};
use linear_moe::coordinator::metrics::{write_csv, LossCurve, Table};
use linear_moe::data;
use linear_moe::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |k: &str, d: &str| -> String {
        args.iter().position(|a| a == k)
            .and_then(|i| args.get(i + 1)).cloned()
            .unwrap_or_else(|| d.to_string())
    };
    let steps: usize = get("--steps", "300").parse()?;
    let lr: f32 = get("--lr", "3e-4").parse()?;
    let batch: usize = get("--batch", "4").parse()?;
    let seq: usize = get("--seq", "256").parse()?;
    let out = get("--out", "results/fig6_loss_curves.csv");
    let tags: Vec<String> = get(
        "--tags",
        "small_attn,small_bla,small_gla,small_mamba2,small_glah,small_mamba2h",
    ).split(',').map(str::to_string).collect();

    let rt = Runtime::new("artifacts")?;
    let mut curves: Vec<LossCurve> = Vec::new();
    let mut summary = Table::new(&["variant", "arch", "final loss (tail-20)",
                                   "tok/s", "params", "activated"]);
    for tag in &tags {
        let var = rt.manifest.variant(tag)?.clone();
        let vocab = var.config.vocab;
        let bf: BatchFn = Arc::new(move |idx, n| {
            let mut lm = data::ZipfLm::new(vocab, 42 + idx as u64);
            let b = data::batch_from_stream(&mut lm, batch, n);
            (b.tokens, b.targets)
        });
        eprintln!("== training {tag} for {steps} steps ==");
        let rep = run_fused("artifacts", tag, batch, seq, lr, steps, bf, 25)?;
        let mut curve = LossCurve::new(tag);
        for (i, l) in rep.losses.iter().enumerate() {
            curve.push(i, *l);
        }
        summary.row(&[
            tag.clone(), var.arch.clone(),
            format!("{:.4}", curve.tail_mean(20)),
            format!("{:.0}", rep.tokens_per_sec),
            var.params_total.to_string(),
            var.params_activated.to_string(),
        ]);
        curves.push(curve);
    }
    std::fs::create_dir_all("results").ok();
    write_csv(&out, &curves.iter().collect::<Vec<_>>())?;
    println!("\n=== Fig 6/7: training convergence ({steps} steps x {batch}x{seq} tokens) ===");
    summary.print();
    println!("loss curves -> {out}");
    Ok(())
}
