//! Distributed-training demo: ZeRO-1 DDP over worker threads with
//! measured collective traffic, equivalence check against the
//! single-worker path, and the distributed-optimizer memory ledger
//! (paper §2.2.3).
//!
//!   cargo run --release --example distributed_training -- [--dp 4] [--steps 4]

use std::sync::Arc;

use linear_moe::coordinator::ddp::{run_ddp, run_single, BatchFn, DdpConfig};
use linear_moe::coordinator::metrics::Table;
use linear_moe::data;
use linear_moe::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |k: &str, d: usize| -> usize {
        args.iter().position(|a| a == k)
            .and_then(|i| args.get(i + 1)).and_then(|s| s.parse().ok())
            .unwrap_or(d)
    };
    let dp = get("--dp", 4);
    let steps = get("--steps", 4);
    let tag = "tiny_gla";
    let rt = Runtime::new("artifacts")?;
    let var = rt.manifest.variant(tag)?.clone();
    drop(rt);
    let vocab = var.config.vocab;
    let bf: BatchFn = Arc::new(move |idx, n| {
        let mut lm = data::ZipfLm::new(vocab, idx as u64);
        let b = data::batch_from_stream(&mut lm, 2, n);
        (b.tokens, b.targets)
    });

    println!("ZeRO-1 DDP: {dp} workers x (2,128) micro-batches, {steps} steps");
    let rep = run_ddp(&DdpConfig {
        artifacts_dir: "artifacts".into(), tag: tag.into(), batch: 2,
        seq: 128, dp, lr: 1e-3, steps, seed: 0,
    }, bf.clone())?;
    let single = run_single("artifacts", tag, 2, 128, 1e-3, steps, bf, dp)?;

    let mut t = Table::new(&["step", "DDP loss", "single+accum loss", "|diff|"]);
    for i in 0..steps {
        t.row(&[i.to_string(), format!("{:.5}", rep.losses[i]),
                format!("{:.5}", single.losses[i]),
                format!("{:.1e}", (rep.losses[i] - single.losses[i]).abs())]);
    }
    t.print();
    let params = var.params_total;
    println!("\ncollective traffic: all-gather {} MiB, reduce-scatter {} MiB",
             rep.traffic.0 / 1048576, rep.traffic.1 / 1048576);
    println!("optimizer state per rank: {} KiB (ZeRO-1: 2 x {params} / {dp} elems)",
             2 * params.div_ceil(dp) * 4 / 1024);
    println!("distributed_training OK");
    Ok(())
}
