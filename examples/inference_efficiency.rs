//! Paper Fig. 5 driver (example form): decode-latency and memory series
//! for Linear-MoE (Basic LA) vs the FlashAttention-2-role Baseline.
//! See also benches/fig5_inference.rs; this example prints the full series
//! and writes a CSV for plotting.
//!
//!   cargo run --release --example inference_efficiency -- [--max-len 4096]

use linear_moe::coordinator::metrics::Table;
use linear_moe::inference::{greedy, AttnDecoder, LsmDecoder};
use linear_moe::memcost;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;
use std::fmt::Write as _;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_len: usize = args.iter().position(|a| a == "--max-len")
        .and_then(|i| args.get(i + 1)).and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    let sizes: Vec<usize> = [128usize, 256, 512, 1024, 2048, 4096]
        .into_iter().filter(|&n| n <= max_len).collect();
    let rt = Runtime::new("artifacts")?;
    let batch = 4;
    let mut lsm = LsmDecoder::new(&rt, "tiny_bla", batch)?;
    let mut attn = AttnDecoder::new(&rt, "tiny_attn", batch, &sizes)?;
    let lsm_cfg = lsm.var.config.clone();
    let attn_cfg = attn.var.config.clone();

    let mut table = Table::new(&["len", "BLA total s", "BLA ms/tok",
                                 "state KiB", "Attn total s", "Attn ms/tok", "KV KiB"]);
    let mut csv = String::from("len,bla_ms_tok,bla_kib,attn_ms_tok,attn_kib\n");
    let mut tok_l = Tensor::i32(&[batch], vec![1; batch]);
    let mut tok_a = tok_l.clone();
    let (mut tl, mut ta) = (0.0f64, 0.0f64);
    let mut pos = 0usize;
    for &end in &sizes {
        let t0 = std::time::Instant::now();
        for p in pos..end {
            tok_l = greedy(&lsm.step(&tok_l, p as i32)?)?;
        }
        let dl = t0.elapsed().as_secs_f64();
        tl += dl;
        let t1 = std::time::Instant::now();
        for p in pos..end {
            tok_a = greedy(&attn.step(&tok_a, p as i32)?)?;
        }
        let da = t1.elapsed().as_secs_f64();
        ta += da;
        let seg = (end - pos) as f64;
        let bla_kib = memcost::decode_state_bytes(&lsm_cfg, batch, end) as f64 / 1024.0;
        let kv_kib = memcost::decode_state_bytes(&attn_cfg, batch, end) as f64 / 1024.0;
        table.row(&[end.to_string(), format!("{tl:.1}"),
                    format!("{:.2}", dl * 1e3 / seg), format!("{bla_kib:.0}"),
                    format!("{ta:.1}"), format!("{:.2}", da * 1e3 / seg),
                    format!("{kv_kib:.0}")]);
        writeln!(csv, "{end},{:.3},{bla_kib:.0},{:.3},{kv_kib:.0}",
                 dl * 1e3 / seg, da * 1e3 / seg)?;
        pos = end;
    }
    println!("\n=== Fig 5: inference efficiency (batch {batch}) ===");
    table.print();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig5_inference.csv", csv)?;
    println!("series -> results/fig5_inference.csv");
    Ok(())
}
