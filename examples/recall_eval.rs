//! Paper Tables 5/6 substitution: pure vs hybrid on recall-intensive
//! tasks.  Trains tiny pure-GLA and hybrid-GLA models on a corpus of
//! phonebook-lookup episodes, then evaluates exact-match recall accuracy
//! with greedy decoding, plus held-out perplexity on the LM corpus.
//!
//! The paper's finding under test: hybrid (attention-carrying) stacks beat
//! pure linear stacks on recall (five-shot MMLU / phonebook / NIAH class),
//! while being comparable on plain LM quality.
//!
//!   cargo run --release --example recall_eval -- [--steps 400] [--episodes 40]

use std::sync::Arc;

use linear_moe::coordinator::ddp::{run_fused, BatchFn};
use linear_moe::coordinator::metrics::Table;
use linear_moe::data;
use linear_moe::eval;
use linear_moe::inference::LsmDecoder;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;

fn recall_batch_fn(vocab: usize, batch: usize, pairs: usize) -> BatchFn {
    Arc::new(move |idx, n| {
        let mut rng = linear_moe::rng::Rng::new(900 + idx as u64);
        let mut toks = Vec::with_capacity(batch * n);
        let mut tgts = Vec::with_capacity(batch * n);
        for _ in 0..batch {
            let mut row = Vec::with_capacity(n + 1);
            while row.len() < n + 1 {
                let ep = data::phonebook_episode(&mut rng, vocab, pairs);
                row.extend_from_slice(&ep.prompt);
                row.push(ep.answer);
            }
            row.truncate(n + 1);
            toks.extend_from_slice(&row[..n]);
            tgts.extend_from_slice(&row[1..n + 1]);
        }
        (Tensor::i32(&[batch, n], toks), Tensor::i32(&[batch, n], tgts))
    })
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |k: &str, d: usize| -> usize {
        args.iter().position(|a| a == k)
            .and_then(|i| args.get(i + 1)).and_then(|s| s.parse().ok())
            .unwrap_or(d)
    };
    let steps = get("--steps", 400);
    let n_eps = get("--episodes", 40);
    let pairs = 8;
    let rt = Runtime::new("artifacts")?;
    let vocab = rt.manifest.variant("tiny_gla")?.config.vocab;

    let mut table = Table::new(&["model", "arch", "phonebook acc",
                                 "train loss (tail)", "held-out ppl"]);
    for tag in ["tiny_gla", "tiny_glah"] {
        let var = rt.manifest.variant(tag)?.clone();
        eprintln!("== training {tag} on phonebook corpus ({steps} steps) ==");
        let bf = recall_batch_fn(vocab, 2, pairs);
        let rep = run_fused("artifacts", tag, 2, 128, 1e-3, steps, bf, 50)?;
        let params = rep.params.clone().unwrap();
        // recall eval with the trained params
        let mut dec = LsmDecoder::new(&rt, tag, 4)?.with_params(params.clone());
        let suite = eval::make_suite(vocab, n_eps, pairs, 0, 0, 1234);
        let rr = eval::recall_eval(&mut dec, &suite)?;
        let ppl = eval::perplexity(&rt, tag, &params, 2, 128, 4, 321)?;
        let tail: f32 = rep.losses[rep.losses.len().saturating_sub(20)..]
            .iter().sum::<f32>() / 20.0;
        table.row(&[tag.to_string(), var.arch.clone(),
                    format!("{:.0}%", rr.accuracy() * 100.0),
                    format!("{tail:.3}"), format!("{ppl:.1}")]);
    }
    println!("\n=== Tables 5/6 substitution: recall-intensive evaluation ===");
    table.print();
    println!("(pure vs hybrid on phonebook lookup; paper finds hybrids \
              stronger on recall-heavy tasks)");
    Ok(())
}
