//! Tracing integration tests: tick-domain determinism of the exported
//! traces, span-derived cross-checks against the hand-maintained
//! counters, exporter well-formedness, and the supervisor fault/recovery
//! timeline.
//!
//! The determinism contract under test: `Tracer::to_jsonl(false)` (wall
//! clock stripped) is bitwise identical across same-seed reruns for the
//! serving engine (single-threaded, tick-based) and the EP-MoE forward
//! (per-rank tracks, per-track program order).  The resilient-DDP path
//! is only checked for event *presence* -- which collective op first
//! observes a poisoned board is timing-dependent, so its error text is
//! a documented nondeterministic field.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use linear_moe::collectives::{Comm, CommCfg};
use linear_moe::coordinator::ddp::{
    run_ddp_resilient, BatchFn, ModelFactory, RankModel, ResilientCfg,
};
use linear_moe::coordinator::metrics::Summary;
use linear_moe::coordinator::moe_ep::{
    forward_ep, DispatchArena, EpCfg, EpStats, ExpertWeights, MoeGeom,
    ReferenceExperts, Strategy,
};
use linear_moe::coordinator::obs;
use linear_moe::fault::{Fault, FaultPlan};
use linear_moe::json;
use linear_moe::rng::Rng;
use linear_moe::serve::{
    poisson_trace, Engine, EngineCfg, FaultDecoder, RefAttnDecoder, RefLsmDecoder,
    Request, Sampling, ServeFault, ServeFaultPlan, ServeReport,
};
use linear_moe::tensor::{Bundle, Tensor};
use linear_moe::trace::TraceHandle;

const VOCAB: usize = 64;
const SEED: u64 = 11;

// ---------------------------------------------------------------- serve

fn serve_requests(n: usize) -> Vec<Request> {
    let mut rng = Rng::new(SEED ^ 0x5157);
    (0..n as u64)
        .map(|id| Request {
            id,
            prompt: (0..6).map(|_| rng.below(VOCAB) as i32).collect(),
            max_new: 8 + rng.below(9),
            eos: None,
            sampling: Sampling::Greedy,
            seed: id,
            ttl: None,
        })
        .collect()
}

fn fault_plan() -> Arc<ServeFaultPlan> {
    Arc::new(ServeFaultPlan::new(vec![
        ServeFault::StepError { step: 10, lane: 1 },
        ServeFault::CorruptState { req: 2, byte: 9 },
        ServeFault::Stall { step: 25, ticks: 3 },
    ]))
}

/// Run the 4-lane engine over the standard trace on the given backend
/// and return (tick-domain JSONL, report, live trace handle).
fn run_serve(attn: bool, faults: bool) -> (String, ServeReport, TraceHandle) {
    let plan = if faults { fault_plan() } else { Arc::new(ServeFaultPlan::none()) };
    let trace = TraceHandle::active();
    let cfg = EngineCfg {
        preempt_after: Some(4),
        max_retries: 4,
        fault: plan.clone(),
        trace: trace.clone(),
        ..Default::default()
    };
    let reqs = serve_requests(12);
    let mut rng = Rng::new(SEED);
    let arrivals = poisson_trace(&mut rng, reqs.len(), 2.0, |id| reqs[id as usize].clone());
    let report = if attn {
        let dec = FaultDecoder::new(RefAttnDecoder::new(4, VOCAB, 16, 16, SEED), plan);
        Engine::new(dec, cfg).unwrap().run_trace(&arrivals).unwrap()
    } else {
        let dec = FaultDecoder::new(RefLsmDecoder::new(4, VOCAB, 16, SEED), plan);
        Engine::new(dec, cfg).unwrap().run_trace(&arrivals).unwrap()
    };
    let jsonl = trace.tracer().unwrap().to_jsonl(false);
    (jsonl, report, trace)
}

#[test]
fn serve_trace_is_bitwise_deterministic_per_backend() {
    for attn in [false, true] {
        for faults in [false, true] {
            let (a, ra, _) = run_serve(attn, faults);
            let (b, rb, _) = run_serve(attn, faults);
            assert!(!a.is_empty(), "trace must not be empty");
            assert_eq!(
                a, b,
                "tick-domain trace must be bitwise stable (attn={attn} faults={faults})"
            );
            assert_eq!(ra.tokens_out, rb.tokens_out);
            assert!(a.contains("\"engine.step\""), "missing engine.step spans");
            assert!(a.contains("\"req.lifecycle\""), "missing lifecycle spans");
            assert!(a.contains("\"req.queued\""), "missing queue instants");
            if faults {
                assert!(ra.faults_injected > 0, "fault plan must fire on this trace");
                assert!(a.contains("\"fault.step\""), "missing injected-fault instant");
                if ra.corruptions_injected > 0 {
                    assert!(a.contains("\"fault.corrupt_state\""));
                }
                if ra.crc_failures > 0 {
                    assert!(a.contains("\"req.crc_fail\""));
                }
                if ra.stalled_ticks > 0 {
                    assert!(a.contains("\"fault.stall\""));
                }
            }
        }
    }
}

#[test]
fn serve_span_occupancy_matches_report_exactly() {
    for faults in [false, true] {
        let (_, report, trace) = run_serve(false, faults);
        let events = trace.tracer().unwrap().sorted_events();
        let occ = obs::span_occupancy(&events).expect("engine.step spans present");
        // both sides are ratios of the same integer counters
        assert_eq!(
            occ,
            report.occupancy(),
            "span-derived occupancy must equal the report (faults={faults})"
        );
    }
}

#[test]
fn serve_perfetto_export_parses_with_expected_spans() {
    let (_, _, trace) = run_serve(false, true);
    let t = trace.tracer().unwrap();
    let parsed = json::parse(&t.to_perfetto(true)).expect("perfetto JSON parses");
    let evs = parsed
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array");
    assert!(!evs.is_empty());
    let phases: Vec<String> =
        evs.iter().filter_map(|e| e.str_field("ph").ok()).collect();
    assert!(phases.iter().any(|p| p == "M"), "process/thread metadata");
    assert!(phases.iter().any(|p| p == "X"), "complete spans");
    assert!(phases.iter().any(|p| p == "i"), "instants");
    let names: Vec<String> =
        evs.iter().filter_map(|e| e.str_field("name").ok()).collect();
    for want in ["engine.step", "req.lifecycle", "fault.step"] {
        assert!(names.iter().any(|n| n == want), "missing {want} in perfetto");
    }
    // registry was auto-absorbed at end of run_trace
    let m = t.metrics_snapshot();
    assert!(m.counter("serve.steps") > 0);
    assert!(m.counter("serve.outcome.finished") > 0);
}

#[test]
fn serve_report_has_percentile_extremes() {
    let (_, report, _) = run_serve(false, false);
    let ttfts: Vec<f64> = report
        .results
        .iter()
        .filter_map(|r| r.ttft().map(|t| t as f64))
        .collect();
    let s = Summary::of(&ttfts);
    assert!(s.n > 0);
    assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
}

// ------------------------------------------------------------------- EP

/// Two-rank chunked+overlapped EP forward with seeded routing; returns
/// (tick-domain JSONL, per-rank stats, handle).
fn run_ep() -> (String, Vec<EpStats>, TraceHandle) {
    let world = 2;
    let (t_local, d, n_experts, top_k, ff) = (32, 16, 4, 2, 32);
    let cap = (t_local * top_k).div_ceil(n_experts) * 2;
    let geom = MoeGeom { d, n_experts, top_k, cap, tile: cap.div_ceil(2).max(1) };
    let cfg = EpCfg { strategy: Strategy::MegaBlocks, chunk: 1, overlap: true };
    let mut wrng = Rng::new(42);
    let backend0 = ReferenceExperts::new(ExpertWeights::random(&mut wrng, n_experts, d, ff));

    let trace = TraceHandle::active();
    let (_comm, handles) =
        Comm::new_with(world, CommCfg { tracer: trace.clone(), ..Default::default() });
    let joins: Vec<_> = handles
        .into_iter()
        .map(|h| {
            let backend = backend0.clone();
            std::thread::spawn(move || -> anyhow::Result<EpStats> {
                let mut arena = DispatchArena::new();
                let mut rng = Rng::new(1000 + h.rank as u64);
                let mut total = EpStats::default();
                for step in 0..3 {
                    h.set_step(step);
                    let x = Tensor::f32(
                        &[t_local, geom.d],
                        (0..t_local * geom.d).map(|_| rng.normal()).collect(),
                    );
                    let mut gates = Vec::new();
                    let mut idx = Vec::new();
                    for _ in 0..t_local * geom.top_k {
                        idx.push(rng.below(geom.n_experts) as i32);
                        gates.push(rng.f32());
                    }
                    let (_y, s) =
                        forward_ep(&h, &backend, &cfg, &geom, &gates, &idx, &x, &mut arena)?;
                    total.comm_wait += s.comm_wait;
                    total.compute += s.compute;
                    total.compute_overlapped += s.compute_overlapped;
                    total.rounds = s.rounds;
                }
                Ok(total)
            })
        })
        .collect();
    let stats: Vec<EpStats> = joins
        .into_iter()
        .map(|j| j.join().expect("EP rank panicked").expect("EP rank failed"))
        .collect();
    let jsonl = trace.tracer().unwrap().to_jsonl(false);
    (jsonl, stats, trace)
}

#[test]
fn ep_trace_is_deterministic_and_overlap_matches_stats() {
    let (a, stats_a, trace) = run_ep();
    let (b, _, _) = run_ep();
    assert!(!a.is_empty());
    assert_eq!(a, b, "EP tick-domain trace must be bitwise stable");
    for want in ["\"ep.dispatch.post\"", "\"ep.wait.data\"", "\"ep.expert\"",
                 "\"ep.wait.return\"", "\"ep.combine\"", "\"a2a.post\"",
                 "\"a2a.wait\""] {
        assert!(a.contains(want), "missing {want} in EP trace");
    }

    // cross-check: overlap fraction re-derived from ep.expert span wall
    // durations vs the Duration sums in EpStats (same measurements)
    let events = trace.tracer().unwrap().sorted_events();
    let span_frac = obs::span_overlap_frac(&events).expect("ep.expert spans present");
    let compute: f64 = stats_a.iter().map(|s| s.compute.as_secs_f64()).sum();
    let overlapped: f64 = stats_a.iter().map(|s| s.compute_overlapped.as_secs_f64()).sum();
    assert!(compute > 0.0);
    let stats_frac = overlapped / compute;
    assert!(
        (span_frac - stats_frac).abs() < 1e-6,
        "span overlap {span_frac} vs stats overlap {stats_frac}"
    );
    assert!(span_frac > 0.0, "chunked overlap=true run must overlap something");
}

// ---------------------------------------------------- resilient training

const DIM: usize = 8;

struct ToyModel;

impl RankModel for ToyModel {
    fn fwd_bwd(
        &mut self,
        params: &Bundle,
        tokens: &Tensor,
        _targets: &Tensor,
    ) -> anyhow::Result<(f32, Bundle)> {
        let p = params.tensors[0].as_f32()?;
        let x = tokens.as_f32()?;
        let mut loss = 0.0f32;
        let mut g = vec![0.0f32; DIM];
        for i in 0..DIM {
            let d = p[i] - x[i];
            loss += 0.5 * d * d;
            g[i] = d;
        }
        Ok((loss, Bundle::new(vec![Tensor::f32(&[DIM], g)])))
    }
}

fn toy_factory() -> ModelFactory {
    Arc::new(|_rank| {
        let params = Bundle::new(vec![Tensor::f32(
            &[DIM],
            (0..DIM).map(|i| 1.0 + i as f32 * 0.25).collect(),
        )]);
        Ok((Box::new(ToyModel) as Box<dyn RankModel>, params))
    })
}

fn toy_batches() -> BatchFn {
    Arc::new(|idx, _seq| {
        let x: Vec<f32> = (0..DIM)
            .map(|i| ((idx * 31 + i * 7) % 13) as f32 * 0.1 - 0.6)
            .collect();
        (Tensor::f32(&[DIM], x), Tensor::scalar_f32(0.0))
    })
}

#[test]
fn resilient_kill_emits_supervisor_timeline() {
    let dir = std::env::temp_dir().join("lmoe_trace_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_path: PathBuf = dir.join("trace_kill.ckpt");
    let _ = std::fs::remove_file(&ckpt_path);
    let _ = std::fs::remove_file(ckpt_path.with_extension("ckpt.prev"));
    let trace = TraceHandle::active();
    let report = run_ddp_resilient(
        &ResilientCfg {
            dp: 2,
            batch: 1,
            seq: DIM,
            lr: 0.05,
            steps: 8,
            save_every: 2,
            max_restarts: 3,
            comm_timeout: Duration::from_secs(5),
            backoff: Duration::from_millis(1),
            ckpt_path,
            faults: Arc::new(FaultPlan::new(vec![Fault::KillRank { rank: 1, step: 5 }])),
            trace: trace.clone(),
        },
        toy_factory(),
        toy_batches(),
    )
    .unwrap();
    assert_eq!(report.recoveries, 1);

    let t = trace.tracer().unwrap();
    let jsonl = t.to_jsonl(false);
    // the whole kill -> rollback -> replay incident on one timeline
    assert!(jsonl.contains("\"fault.kill\""), "injected kill instant missing");
    assert!(jsonl.contains("\"attempt.failed\""), "supervisor failure missing");
    assert!(
        jsonl.contains("\"recovery.rollback\""),
        "rollback instant missing: {jsonl}"
    );
    assert!(jsonl.contains("\"supervisor\""), "supervisor track missing");
    assert!(jsonl.contains("\"comm."), "per-rank collective spans missing");
    // health snapshot was absorbed into the registry on success
    let m = t.metrics_snapshot();
    assert_eq!(m.counter("health.restarts"), 1);
    assert_eq!(m.counter("fault.injected_kills"), 1);
    // perfetto side stays loadable with the supervisor track present
    let parsed = json::parse(&t.to_perfetto(true)).unwrap();
    let evs = parsed.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    assert!(evs
        .iter()
        .any(|e| e.str_field("name").ok().as_deref() == Some("recovery.rollback")));
}

// ----------------------------------------------------------- percentiles

#[test]
fn summary_percentile_edge_cases() {
    let z = Summary::of(&[]);
    assert_eq!((z.n, z.mean, z.min, z.p50, z.p99, z.max), (0, 0.0, 0.0, 0.0, 0.0, 0.0));

    let one = Summary::of(&[7.0]);
    assert_eq!((one.n, one.min, one.p50, one.p95, one.p99, one.max),
               (1, 7.0, 7.0, 7.0, 7.0, 7.0));

    // even n: nearest-rank convention, idx = floor(n*q) clamped
    let even = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
    assert_eq!((even.n, even.min, even.p50, even.p99, even.max),
               (4, 1.0, 3.0, 4.0, 4.0));

    // NaN/inf never panic and never poison the order stats
    let s = Summary::of(&[f64::NAN, 2.0, f64::INFINITY, 1.0, f64::NEG_INFINITY]);
    assert_eq!((s.n, s.min, s.max), (2, 1.0, 2.0));
    let all_bad = Summary::of(&[f64::NAN, f64::NAN]);
    assert_eq!(all_bad.n, 0);
}
