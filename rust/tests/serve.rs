//! Continuous-batching engine property suite (artifact-free).
//!
//! The load-bearing property: with a reference step backend, a trace of
//! staggered requests through a multi-lane engine produces per-request
//! token streams *bitwise identical* to running each request alone
//! single-stream -- continuous batching (admission, prefill-in-the-loop,
//! preemption, state swapping) is semantics-preserving.  Plus queue
//! backpressure, arena reuse, and determinism checks.

use linear_moe::inference::Decoder;
use linear_moe::rng::Rng;
use linear_moe::serve::engine::run_one;
use linear_moe::serve::{
    poisson_trace, Arrival, Engine, EngineCfg, Outcome, RefAttnDecoder,
    RefLsmDecoder, Request, Sampling,
};

const VOCAB: usize = 64;
const MODEL_SEED: u64 = 99;

fn mixed_requests(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let plen = 1 + rng.below(6);
            let prompt = (0..plen).map(|_| rng.below(VOCAB) as i32).collect();
            let sampling = match id % 3 {
                0 => Sampling::Greedy,
                1 => Sampling::Temperature { temp: 0.9 },
                _ => Sampling::TopK { k: 5, temp: 1.1 },
            };
            Request {
                id,
                prompt,
                max_new: 4 + rng.below(8),
                eos: if id % 4 == 0 { Some(3) } else { None },
                sampling,
                seed: 1000 + id,
                ttl: None,
            }
        })
        .collect()
}

fn lsm(lanes: usize) -> RefLsmDecoder {
    RefLsmDecoder::new(lanes, VOCAB, 16, MODEL_SEED)
}

fn attn(lanes: usize) -> RefAttnDecoder {
    RefAttnDecoder::new(lanes, VOCAB, 8, 8, MODEL_SEED)
}

fn staggered(reqs: &[Request], gap: f64, seed: u64) -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    poisson_trace(&mut rng, reqs.len(), gap, |id| reqs[id as usize].clone())
}

/// Engine outputs must equal per-request single-stream decoding, bitwise.
fn assert_matches_single_stream<D, F>(
    engine_dec: D,
    fresh: F,
    cfg: EngineCfg,
    n: usize,
) -> linear_moe::serve::ServeReport
where
    D: Decoder,
    F: Fn() -> D,
{
    let reqs = mixed_requests(n, 7);
    let trace = staggered(&reqs, 2.0, 21);
    let mut engine = Engine::new(engine_dec, cfg).expect("engine");
    let report = engine.run_trace(&trace).expect("engine trace");
    assert_eq!(report.results.len(), n, "every request must finish");
    assert!(report.outcomes.all_finished(), "no deadlines or faults in play");
    assert_eq!(report.outcomes.finished, n as u64);
    for r in &report.results {
        let mut solo = fresh();
        let want = run_one(&mut solo, &reqs[r.id as usize]).expect("single-stream");
        assert_eq!(
            r.tokens, want,
            "request {} diverged from single-stream decode",
            r.id
        );
        assert_eq!(r.outcome, Outcome::Finished);
        let admit = r.admit_tick.expect("finished request was admitted");
        let first = r.first_token_tick.expect("finished request sampled");
        assert!(admit >= r.arrival_tick);
        assert!(first >= admit);
        assert!(r.finish_tick >= first);
        assert!(!r.tokens.is_empty() && r.tokens.len() <= reqs[r.id as usize].max_new);
    }
    report
}

#[test]
fn lsm_engine_matches_single_stream_with_occupancy() {
    // acceptance: >= 32 staggered requests, 4 lanes, bitwise identity,
    // average lane occupancy > 1
    let report =
        assert_matches_single_stream(lsm(4), || lsm(1), EngineCfg::default(), 40);
    assert!(
        report.occupancy() > 1.0,
        "continuous batching should keep more than one lane busy \
         (occupancy {:.2})",
        report.occupancy()
    );
    assert_eq!(report.swaps, 0, "no preemption configured");
}

#[test]
fn lsm_engine_matches_single_stream_under_preemption() {
    let cfg = EngineCfg { preempt_after: Some(3), ..Default::default() };
    let report = assert_matches_single_stream(lsm(4), || lsm(1), cfg, 40);
    assert!(report.swaps > 0, "quantum of 3 over 40 requests must swap");
    assert!(
        report.results.iter().any(|r| r.preemptions > 0),
        "some request must have been preempted"
    );
}

#[test]
fn attn_engine_matches_single_stream() {
    // per-lane positions genuinely diverge across lanes here: the
    // reference attention backend handles ragged positions, unlike the
    // scalar-pos PJRT staircase artifacts
    let report =
        assert_matches_single_stream(attn(4), || attn(1), EngineCfg::default(), 32);
    assert!(report.occupancy() > 1.0);
}

#[test]
fn attn_engine_matches_single_stream_under_preemption() {
    let cfg = EngineCfg { preempt_after: Some(2), ..Default::default() };
    let report = assert_matches_single_stream(attn(4), || attn(1), cfg, 32);
    assert!(report.swaps > 0);
}

#[test]
fn backpressure_bounces_then_serves_all() {
    let reqs = mixed_requests(24, 13);
    let trace: Vec<Arrival> = reqs
        .iter()
        .map(|r| Arrival { at_tick: 0, req: r.clone() })
        .collect();
    let cfg = EngineCfg { max_pending: 2, ..Default::default() };
    let mut engine = Engine::new(lsm(4), cfg).expect("engine");
    let report = engine.run_trace(&trace).expect("trace");
    assert!(report.rejected > 0, "depth-2 queue must bounce a burst of 24");
    assert_eq!(report.results.len(), 24, "bounced requests retry and finish");
    for r in &report.results {
        let mut solo = lsm(1);
        let want = run_one(&mut solo, &reqs[r.id as usize]).unwrap();
        assert_eq!(r.tokens, want, "backpressure must not corrupt streams");
    }
}

#[test]
fn engine_is_deterministic() {
    let run = || {
        let reqs = mixed_requests(20, 3);
        let trace = staggered(&reqs, 1.5, 4);
        let cfg = EngineCfg { preempt_after: Some(2), ..Default::default() };
        Engine::new(lsm(3), cfg).unwrap().run_trace(&trace).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.finish_tick, y.finish_tick);
        assert_eq!(x.preemptions, y.preemptions);
    }
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.swap_bytes, b.swap_bytes);
}

#[test]
fn state_arena_reuses_buffers_in_steady_state() {
    // 2 lanes, 4 long requests, quantum 1: constant rotation.  The free
    // list reaches 4 LaneState buffers (one tensor each) and then every
    // further swap reuses them -- the zero-realloc session pool claim.
    let reqs: Vec<Request> = (0..4u64)
        .map(|id| Request {
            id,
            prompt: vec![5, 9],
            max_new: 50,
            eos: None,
            sampling: Sampling::Greedy,
            seed: id,
            ttl: None,
        })
        .collect();
    let trace: Vec<Arrival> = reqs
        .iter()
        .map(|r| Arrival { at_tick: 0, req: r.clone() })
        .collect();
    let cfg = EngineCfg { preempt_after: Some(1), ..Default::default() };
    let mut engine = Engine::new(lsm(2), cfg).expect("engine");
    let report = engine.run_trace(&trace).expect("trace");
    assert!(report.swaps > 50, "rotation must swap a lot ({})", report.swaps);
    assert!(
        report.state_reallocs <= 4,
        "steady-state swapping must not allocate (reallocs {})",
        report.state_reallocs
    );
    // and the rotation preserved every stream
    for r in &report.results {
        let mut solo = lsm(1);
        assert_eq!(r.tokens, run_one(&mut solo, &reqs[r.id as usize]).unwrap());
    }
}

#[test]
fn lsm_lane_state_is_constant_while_attn_grows() {
    let l = lsm(2);
    assert_eq!(l.lane_state_bytes(1), l.lane_state_bytes(4096));
    let a = attn(2);
    assert!(a.lane_state_bytes(4096) > a.lane_state_bytes(16));
    let mid = a.lane_state_bytes(100);
    assert!(
        a.lane_state_bytes(16) <= mid && mid <= a.lane_state_bytes(4096),
        "staircase must be monotone"
    );
}
