//! Expert-parallel MoE correctness: `forward_ep` over ep_world ∈ {1,2,4}
//! must be element-wise **bit-identical** to the single-rank
//! `forward_tokens` reference for all three strategies -- within-capacity
//! batches, ragged token counts that leave experts empty, over-capacity
//! batches that force drops, and every chunk/overlap combination.
//!
//! Everything here runs on the pure-Rust [`ReferenceExperts`] backend, so
//! no compiled artifacts (and no PJRT) are required -- the same pattern as
//! tests/fault_tolerance.rs.

use std::sync::Arc;
use std::thread;

use linear_moe::collectives::Comm;
use linear_moe::coordinator::moe_ep::{
    forward_ep, forward_tokens, DispatchArena, EpCfg, EpStats, ExpertWeights,
    MoeGeom, ReferenceExperts, Strategy,
};
use linear_moe::rng::{check, Rng};
use linear_moe::tensor::Tensor;

const STRATEGIES: [Strategy; 3] =
    [Strategy::Loop, Strategy::Grouped, Strategy::MegaBlocks];

/// A routed toy batch: global tokens, gates, and expert indices.
struct Batch {
    geom: MoeGeom,
    weights: ExpertWeights,
    xv: Vec<f32>,
    gates: Vec<f32>,
    idx: Vec<i32>,
    t: usize,
}

/// Build a batch whose global token count divides `ep_world`.  `skew`
/// routes everything into the first expert of each rank-block so some
/// experts stay empty (ragged) and cap strategies drop rows.
fn make_batch(rng: &mut Rng, ep_world: usize, cap: usize, skew: bool) -> Batch {
    let epr = 1 + rng.below(3); // experts per rank
    let e = ep_world * epr;
    let k = 1 + rng.below(2.min(e));
    let t = ep_world * (1 + rng.below(12)); // equal tokens per rank
    let d = 1 + rng.below(5);
    let f = 1 + rng.below(6);
    let weights = ExpertWeights::random(rng, e, d, f);
    let geom = MoeGeom { d, n_experts: e, top_k: k, cap, tile: 1 + rng.below(3) };
    let xv: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
    let mut gates = Vec::with_capacity(t * k);
    let mut idx = Vec::with_capacity(t * k);
    for _ in 0..t * k {
        let ex = if skew {
            (rng.below(ep_world) * epr) as i32 // first expert of a block
        } else {
            rng.below(e) as i32
        };
        idx.push(ex);
        gates.push(rng.f32());
    }
    Batch { geom, weights, xv, gates, idx, t }
}

/// Run `forward_ep` SPMD over `ep_world` threads on rank-partitioned
/// slices of the batch and reassemble the global output in rank order.
fn run_ep(b: &Batch, ep_world: usize, cfg: EpCfg) -> (Vec<f32>, Vec<EpStats>) {
    let t_local = b.t / ep_world;
    let (d, k) = (b.geom.d, b.geom.top_k);
    let backend0 = ReferenceExperts::new(b.weights.clone());
    let (_comm, handles) = Comm::new(ep_world);
    let shared = Arc::new((b.xv.clone(), b.gates.clone(), b.idx.clone()));
    let geom = b.geom;
    let joins: Vec<_> = handles
        .into_iter()
        .map(|h| {
            let backend = backend0.clone();
            let shared = shared.clone();
            thread::spawn(move || {
                let (xv, gates, idx) = &*shared;
                let r = h.rank;
                let x = Tensor::f32(
                    &[t_local, d],
                    xv[r * t_local * d..(r + 1) * t_local * d].to_vec(),
                );
                let g = &gates[r * t_local * k..(r + 1) * t_local * k];
                let i = &idx[r * t_local * k..(r + 1) * t_local * k];
                let mut arena = DispatchArena::new();
                let (y, stats) =
                    forward_ep(&h, &backend, &cfg, &geom, g, i, &x, &mut arena).unwrap();
                (r, y.as_f32().unwrap().to_vec(), stats)
            })
        })
        .collect();
    let mut out = vec![0f32; b.t * d];
    let mut stats = vec![EpStats::default(); ep_world];
    for j in joins {
        let (r, y, s) = j.join().unwrap();
        out[r * t_local * d..(r + 1) * t_local * d].copy_from_slice(&y);
        stats[r] = s;
    }
    (out, stats)
}

fn single_rank(b: &Batch, strategy: Strategy) -> Vec<f32> {
    let backend = ReferenceExperts::new(b.weights.clone());
    let mut arena = DispatchArena::new();
    let (y, _, _, _) = forward_tokens(
        &backend, strategy, &b.geom, &b.gates, &b.idx, &b.xv, b.t, &mut arena,
    )
    .unwrap();
    y
}

#[test]
fn ep_equals_single_rank_all_strategies_and_worlds() {
    check("ep_equals_single_rank", 12, |rng: &mut Rng| {
        let skew = rng.below(2) == 0;
        for ep_world in [1usize, 2, 4] {
            let b = make_batch(rng, ep_world, 64, skew); // generous cap: no drops
            for strategy in STRATEGIES {
                let want = single_rank(&b, strategy);
                let cfg = EpCfg { strategy, chunk: 0, overlap: true };
                let (got, _) = run_ep(&b, ep_world, cfg);
                assert_eq!(got, want, "{strategy} ep={ep_world} skew={skew}");
            }
        }
    });
}

#[test]
fn ep_capacity_drops_match_single_rank_bitwise() {
    // tight capacity: the same rows must be dropped on both paths, and the
    // surviving accumulation must stay bit-identical
    check("ep_capacity_drops", 10, |rng: &mut Rng| {
        for ep_world in [2usize, 4] {
            let b = make_batch(rng, ep_world, 2, true); // cap 2, skewed: drops
            for strategy in [Strategy::Loop, Strategy::Grouped] {
                let want = single_rank(&b, strategy);
                let (got, stats) = run_ep(
                    &b, ep_world,
                    EpCfg { strategy, chunk: 0, overlap: false },
                );
                assert_eq!(got, want, "{strategy} ep={ep_world}");
                let dropped: usize = stats.iter().map(|s| s.dropped_rows).sum();
                let kept = b.t * b.geom.top_k - dropped;
                assert!(kept <= b.geom.n_experts * b.geom.cap);
            }
        }
    });
}

#[test]
fn ep_chunked_equals_unchunked_under_all_modes() {
    check("ep_chunking_invariant", 8, |rng: &mut Rng| {
        let b = make_batch(rng, 2, 64, false);
        for strategy in STRATEGIES {
            let want = single_rank(&b, strategy);
            for chunk in [0usize, 1, 2, 3] {
                for overlap in [false, true] {
                    let (got, _) =
                        run_ep(&b, 2, EpCfg { strategy, chunk, overlap });
                    assert_eq!(
                        got, want,
                        "{strategy} chunk={chunk} overlap={overlap}"
                    );
                }
            }
        }
    });
}

#[test]
fn ep_overlap_fraction_reported() {
    let mut rng = Rng::new(99);
    // 4 experts per rank, chunk 1 -> 4 rounds: overlap mode must report
    // overlapped compute; sequential mode must report none.
    let epr = 4;
    let b = {
        let mut b = make_batch(&mut rng, 2, 64, false);
        // rebuild with fixed expert count for a guaranteed multi-round run
        let e = 2 * epr;
        let weights = ExpertWeights::random(&mut rng, e, b.geom.d, 3);
        let mut idx = Vec::new();
        let mut gates = Vec::new();
        for _ in 0..b.t * b.geom.top_k {
            idx.push(rng.below(e) as i32);
            gates.push(rng.f32());
        }
        b.geom.n_experts = e;
        b.weights = weights;
        b.idx = idx;
        b.gates = gates;
        b
    };
    let (_, stats) = run_ep(&b, 2, EpCfg {
        strategy: Strategy::MegaBlocks, chunk: 1, overlap: true,
    });
    assert_eq!(stats[0].rounds, epr);
    assert_eq!(
        stats[0].compute_overlapped, stats[0].compute,
        "with rounds >= 2 every launch runs under an in-flight shard"
    );
    assert!(
        stats[0].compute > std::time::Duration::ZERO
            && stats[0].overlap_frac() > 0.0,
        "multi-round overlapped run must overlap compute with comm"
    );
    let (_, stats) = run_ep(&b, 2, EpCfg {
        strategy: Strategy::MegaBlocks, chunk: 1, overlap: false,
    });
    assert_eq!(stats[0].overlap_frac(), 0.0, "sequential mode must not overlap");
}

#[test]
fn ep_arena_stays_flat_after_warmup() {
    // fixed shapes: after the first forward the arena must stop allocating
    let mut rng = Rng::new(7);
    let e = 4;
    let (d, f, t, k) = (3, 5, 8, 2);
    let weights = ExpertWeights::random(&mut rng, e, d, f);
    let geom = MoeGeom { d, n_experts: e, top_k: k, cap: 8, tile: 2 };
    let backend = ReferenceExperts::new(weights.clone());
    let xv: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
    let mut gates = Vec::new();
    let mut idx = Vec::new();
    for _ in 0..t * k {
        idx.push(rng.below(e) as i32);
        gates.push(rng.f32());
    }
    let (_comm, mut handles) = Comm::new(1);
    let h = handles.remove(0);
    let cfg = EpCfg { strategy: Strategy::MegaBlocks, chunk: 0, overlap: true };
    let x = Tensor::f32(&[t, d], xv);
    let mut arena = DispatchArena::new();
    forward_ep(&h, &backend, &cfg, &geom, &gates, &idx, &x, &mut arena).unwrap();
    let after_warmup = arena.alloc_events();
    for _ in 0..6 {
        forward_ep(&h, &backend, &cfg, &geom, &gates, &idx, &x, &mut arena).unwrap();
    }
    assert_eq!(
        arena.alloc_events(),
        after_warmup,
        "dispatch arena must not grow after warmup"
    );
}
