//! Chaos suite for the serving engine: deterministic fault injection
//! must never cost a *correct* token.
//!
//! The invariant under test strengthens `tests/serve.rs`: with step
//! errors, lane-state bit-rot, stalls, deadlines, and preemption all in
//! play, every request that `Finished` is still bitwise identical to its
//! single-stream reference (`run_one`), every `Failed`/`Expired` request
//! carries a strict *prefix* of that reference (never wrong tokens), and
//! every `Shed` request carries nothing.  Corrupted lane-state images are
//! always caught by the CRC check before they are decoded from, and the
//! whole circus is bit-for-bit reproducible from its seeds.

use std::sync::Arc;

use anyhow::Result;
use linear_moe::inference::{Decoder, LaneState};
use linear_moe::rng::{self, Rng};
use linear_moe::serve::{
    poisson_trace, run_one, Arrival, Engine, EngineCfg, EngineError, FaultDecoder,
    Outcome, RefAttnDecoder, RefLsmDecoder, Request, Sampling, ServeFaultPlan,
    ServeReport,
};
use linear_moe::tensor::Tensor;

const VOCAB: usize = 64;
const MODEL_SEED: u64 = 99;

fn lsm(lanes: usize) -> RefLsmDecoder {
    RefLsmDecoder::new(lanes, VOCAB, 16, MODEL_SEED)
}

fn attn(lanes: usize) -> RefAttnDecoder {
    RefAttnDecoder::new(lanes, VOCAB, 8, 8, MODEL_SEED)
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize, ttl: Option<u64>) -> Request {
    let sampling = match id % 3 {
        0 => Sampling::Greedy,
        1 => Sampling::Temperature { temp: 0.9 },
        _ => Sampling::TopK { k: 5, temp: 1.1 },
    };
    Request { id, prompt, max_new, eos: None, sampling, seed: 1000 + id, ttl }
}

fn mixed(n: usize, seed: u64, ttl: impl Fn(u64) -> Option<u64>) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let plen = 1 + rng.below(6);
            let prompt = (0..plen).map(|_| rng.below(VOCAB) as i32).collect();
            req(id, prompt, 4 + rng.below(8), ttl(id))
        })
        .collect()
}

fn burst(reqs: &[Request]) -> Vec<Arrival> {
    reqs.iter().map(|r| Arrival { at_tick: 0, req: r.clone() }).collect()
}

/// The chaos contract, checked for every result against a fresh 1-lane
/// reference decoder.
fn check_contract<D: Decoder, F: Fn() -> D>(report: &ServeReport, reqs: &[Request], fresh: F) {
    for r in &report.results {
        let mut solo = fresh();
        let want = run_one(&mut solo, &reqs[r.id as usize]).expect("reference");
        match r.outcome {
            Outcome::Finished => assert_eq!(
                r.tokens, want,
                "finished request {} diverged from single-stream",
                r.id
            ),
            Outcome::Expired | Outcome::Failed { .. } => {
                assert!(
                    want.starts_with(&r.tokens),
                    "request {} ({:?}) emitted non-prefix tokens {:?} (want {:?})",
                    r.id,
                    r.outcome,
                    r.tokens,
                    want
                );
                assert!(r.tokens.len() < want.len(), "partial outcome with full stream");
            }
            Outcome::Shed => {
                assert!(r.tokens.is_empty(), "shed request {} has tokens", r.id);
                assert!(r.admit_tick.is_none(), "shed request {} held a lane", r.id);
            }
        }
    }
}

/// Injected decode-step faults: victims recover by replay and finish
/// bitwise; everyone else never notices.  Exercised on both backends and
/// repeated to pin determinism under faults.
fn step_faults_recover<D: Decoder, F: Fn(usize) -> D>(make: F, spec: &str, expect: u64) {
    let run = || {
        let plan = Arc::new(ServeFaultPlan::parse(spec).unwrap());
        let reqs = mixed(24, 7, |_| None);
        let cfg = EngineCfg { fault: plan.clone(), ..Default::default() };
        let mut engine =
            Engine::new(FaultDecoder::new(make(4), plan), cfg).expect("engine");
        let report = engine.run_trace(&burst(&reqs)).expect("trace");
        (report, reqs)
    };
    let (report, reqs) = run();
    assert_eq!(report.faults_injected, expect, "all planned faults must fire");
    assert_eq!(report.outcomes.finished, 24, "defaults give enough retries");
    assert!(report.outcomes.recovered >= 1, "a victim must have replayed");
    assert!(
        report.results.iter().map(|r| r.retries as u64).sum::<u64>() >= 1,
        "victims record their replays"
    );
    check_contract(&report, &reqs, || make(1));
    // chaos is reproducible: identical plan + trace => identical run
    let (again, _) = run();
    for (x, y) in report.results.iter().zip(&again.results) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.outcome, y.outcome);
        assert_eq!(x.retries, y.retries);
    }
    assert_eq!(report.ticks, again.ticks);
}

#[test]
fn step_faults_recover_bitwise_lsm() {
    step_faults_recover(lsm, "step_err:step=4,lane=1;step_err:step=9,lane=3", 2);
}

#[test]
fn step_faults_recover_bitwise_attn() {
    step_faults_recover(attn, "step_err:step=3,lane=0;step_err:step=7,lane=2", 2);
}

#[test]
fn retry_budget_exhaustion_fails_with_prefix() {
    // 1 lane, zero retries: the fault at attempt 1 retires request 0 as
    // Failed with the one token it already sampled -- a prefix, kept for
    // the postmortem.  The next request runs clean on the same lane.
    let plan = Arc::new(ServeFaultPlan::parse("step_err:step=1,lane=0").unwrap());
    let reqs = vec![
        req(0, vec![5], 4, None),      // samples from attempt 0
        req(1, vec![6, 7], 3, None),
    ];
    let cfg = EngineCfg { fault: plan.clone(), max_retries: 0, ..Default::default() };
    let mut engine = Engine::new(FaultDecoder::new(lsm(1), plan), cfg).unwrap();
    let report = engine.run_trace(&burst(&reqs)).unwrap();
    assert_eq!(report.faults_injected, 1);
    assert_eq!(report.outcomes.failed, 1);
    assert_eq!(report.outcomes.finished, 1);
    let failed = &report.results[0];
    assert_eq!(failed.id, 0);
    assert_eq!(failed.outcome, Outcome::Failed { retries: 0 });
    assert_eq!(failed.tokens.len(), 1, "the pre-fault token survives");
    check_contract(&report, &reqs, || lsm(1));
    // goodput counts only the finished request's tokens
    assert_eq!(report.tokens_out, report.results[1].tokens.len() as u64);
}

/// Lane-state bit-rot: the image is corrupted after CRC stamping; resume
/// must detect it (never decode from garbage) and recover by replay.
fn corruption_recovers<D: Decoder, F: Fn(usize) -> D>(make: F) {
    let plan = Arc::new(ServeFaultPlan::parse("corrupt_state:req=2,byte=5").unwrap());
    let reqs: Vec<Request> =
        (0..4).map(|id| req(id, vec![5, 9], 12, None)).collect();
    let cfg = EngineCfg {
        preempt_after: Some(1),
        fault: plan.clone(),
        ..Default::default()
    };
    let mut engine = Engine::new(FaultDecoder::new(make(2), plan), cfg).unwrap();
    let report = engine.run_trace(&burst(&reqs)).unwrap();
    assert_eq!(report.corruptions_injected, 1, "rotation must preempt req 2");
    assert_eq!(report.crc_failures, 1, "corrupt image must be caught at check-in");
    assert_eq!(report.outcomes.finished, 4);
    assert!(report.outcomes.recovered >= 1);
    let victim = &report.results[2];
    assert!(victim.retries >= 1, "victim must have replayed");
    check_contract(&report, &reqs, || make(1));
}

#[test]
fn corruption_detected_and_recovered_lsm() {
    corruption_recovers(lsm);
}

#[test]
fn corruption_detected_and_recovered_attn() {
    corruption_recovers(attn);
}

#[test]
fn stall_burns_ticks_and_deadlines_expire() {
    // a 40-tick stall from attempt 2 holds both lanes past the 20-tick
    // TTL: the engine expires the sessions (prefix tokens kept) instead
    // of hanging
    let plan = Arc::new(ServeFaultPlan::parse("stall:step=2,ticks=40").unwrap());
    let reqs = vec![
        req(0, vec![5, 9], 6, Some(20)),
        req(1, vec![7, 3], 6, Some(20)),
    ];
    let cfg = EngineCfg { fault: plan.clone(), ..Default::default() };
    let mut engine = Engine::new(FaultDecoder::new(lsm(2), plan), cfg).unwrap();
    let report = engine.run_trace(&burst(&reqs)).unwrap();
    assert!(report.stalled_ticks >= 1, "the stall must burn ticks");
    assert_eq!(report.outcomes.expired, 2);
    for r in &report.results {
        assert_eq!(r.outcome, Outcome::Expired);
        assert_eq!(r.deadline, Some(20));
        assert!(r.finish_tick > 20, "expiry happens after the deadline passes");
        assert!(r.deadline_miss().unwrap_or(0) >= 1);
        assert_eq!(r.tokens.len(), 1, "one token sampled before the stall");
    }
    check_contract(&report, &reqs, || lsm(1));
    assert_eq!(report.tokens_out, 0, "expired tokens are not goodput");
}

#[test]
fn admission_sheds_hopeless_deadlines() {
    // request 1 needs 8 lane steps but only has a 3-tick TTL: shed at the
    // door with zero lane steps spent; the rest finish bitwise
    let reqs = vec![
        req(0, vec![5, 9], 6, Some(100)),
        req(1, vec![2, 4], 7, Some(3)),
        req(2, vec![8], 5, None),
        req(3, vec![1, 6, 2], 4, Some(100)),
    ];
    let mut engine = Engine::new(lsm(2), EngineCfg::default()).unwrap();
    let report = engine.run_trace(&burst(&reqs)).unwrap();
    assert_eq!(report.outcomes.shed, 1);
    assert_eq!(report.outcomes.finished, 3);
    let shed = &report.results[1];
    assert_eq!(shed.outcome, Outcome::Shed);
    assert!(shed.tokens.is_empty() && shed.admit_tick.is_none());
    assert!(shed.first_token_tick.is_none());
    assert!(shed.deadline_miss().is_none(), "shedding beats missing");
    check_contract(&report, &reqs, || lsm(1));
}

#[test]
fn seeded_chaos_property() {
    // randomized soak: seeded step-error storms + deadlines + preemption
    // + a tight retry budget, on a 4-lane engine.  Whatever happens, the
    // outcome contract holds and the run replays bit-for-bit.
    rng::check("serve_chaos", 8, |rng| {
        let seed = rng.next_u64();
        let run = |seed: u64| {
            let plan =
                Arc::new(ServeFaultPlan::seeded_step_errors(seed, 300, 4, 0.08));
            let reqs = mixed(16, seed ^ 0xFEED, |id| {
                (id % 3 == 0).then_some(20 + 3 * id)
            });
            let mut arrival_rng = Rng::new(seed ^ 1);
            let trace = poisson_trace(&mut arrival_rng, reqs.len(), 1.5, |id| {
                reqs[id as usize].clone()
            });
            let cfg = EngineCfg {
                preempt_after: Some(2),
                max_retries: 1,
                fault: plan.clone(),
                ..Default::default()
            };
            let mut engine =
                Engine::new(FaultDecoder::new(lsm(4), plan), cfg).unwrap();
            (engine.run_trace(&trace).unwrap(), reqs)
        };
        let (a, reqs) = run(seed);
        assert_eq!(a.outcomes.total(), 16, "every request lands in one bucket");
        assert_eq!(a.results.len(), 16);
        check_contract(&a, &reqs, || lsm(1));
        let (b, _) = run(seed);
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.faults_injected, b.faults_injected);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.outcome, y.outcome);
            assert_eq!(x.finish_tick, y.finish_tick);
        }
    });
}

/// A decoder that (like the scalar-pos PJRT attention path) cannot serve
/// lanes at independent positions.
struct AlignedOnly {
    inner: RefLsmDecoder,
}

impl Decoder for AlignedOnly {
    fn lanes(&self) -> usize {
        self.inner.lanes()
    }

    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor> {
        self.inner.decode_step(tokens, pos)
    }

    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()> {
        self.inner.save_lane(lane, out)
    }

    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()> {
        self.inner.load_lane(lane, src)
    }

    fn reset_lane(&mut self, lane: usize) -> Result<()> {
        self.inner.reset_lane(lane)
    }

    fn lane_state_bytes(&self, pos: usize) -> usize {
        self.inner.lane_state_bytes(pos)
    }

    fn aligned_lanes_only(&self) -> bool {
        true
    }
}

#[test]
fn aligned_only_decoder_rejected_at_construction() {
    // multi-lane ragged scheduling over an aligned-only decoder is a
    // typed construction error, not a wrong-token surprise at runtime
    let err = Engine::new(AlignedOnly { inner: lsm(4) }, EngineCfg::default())
        .err()
        .expect("4 ragged lanes must be rejected");
    assert!(matches!(
        err.downcast_ref::<EngineError>(),
        Some(EngineError::AlignedLanesOnly { lanes: 4 })
    ));
    // a single lane is trivially aligned: allowed, and it still serves
    let reqs = vec![req(0, vec![5], 3, None)];
    let mut engine =
        Engine::new(AlignedOnly { inner: lsm(1) }, EngineCfg::default()).unwrap();
    let report = engine.run_trace(&burst(&reqs)).unwrap();
    assert_eq!(report.outcomes.finished, 1);
}
