//! Fault-tolerance integration tests: deterministic fault injection into
//! the resilient DDP trainer, checkpoint rollback, and loss equivalence
//! with the uninterrupted run.
//!
//! These tests use a pure-Rust toy model (quadratic loss) behind the
//! `RankModel` trait, so they exercise the full recovery machinery --
//! collectives, ZeRO-1 optimizer, checkpoints, supervisor -- without any
//! PJRT artifacts.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use linear_moe::coordinator::ddp::{
    run_ddp_resilient, BatchFn, ModelFactory, RankModel, ResilientCfg,
};
use linear_moe::fault::{Fault, FaultPlan};
use linear_moe::tensor::{Bundle, Tensor};

const DIM: usize = 8;

/// Quadratic toy model: loss = 0.5 * sum((p - x)^2), grad = p - x, where
/// x is the "batch".  Deterministic and cheap, but the gradient depends
/// on both the params and the per-rank micro-batch, so the grad
/// all-reduce and ZeRO-1 all-gather are genuinely load-bearing.
struct ToyModel;

impl RankModel for ToyModel {
    fn fwd_bwd(
        &mut self,
        params: &Bundle,
        tokens: &Tensor,
        _targets: &Tensor,
    ) -> anyhow::Result<(f32, Bundle)> {
        let p = params.tensors[0].as_f32()?;
        let x = tokens.as_f32()?;
        let mut loss = 0.0f32;
        let mut g = vec![0.0f32; DIM];
        for i in 0..DIM {
            let d = p[i] - x[i];
            loss += 0.5 * d * d;
            g[i] = d;
        }
        Ok((loss, Bundle::new(vec![Tensor::f32(&[DIM], g)])))
    }
}

fn toy_factory() -> ModelFactory {
    Arc::new(|_rank| {
        let params = Bundle::new(vec![Tensor::f32(
            &[DIM],
            (0..DIM).map(|i| 1.0 + i as f32 * 0.25).collect(),
        )]);
        Ok((Box::new(ToyModel) as Box<dyn RankModel>, params))
    })
}

/// Deterministic per-(global micro-batch) data, addressed by step index
/// so replay after rollback sees identical batches.
fn toy_batches() -> BatchFn {
    Arc::new(|idx, _seq| {
        let x: Vec<f32> = (0..DIM)
            .map(|i| ((idx * 31 + i * 7) % 13) as f32 * 0.1 - 0.6)
            .collect();
        (Tensor::f32(&[DIM], x), Tensor::scalar_f32(0.0))
    })
}

fn cfg(
    name: &str,
    steps: usize,
    save_every: usize,
    max_restarts: usize,
    faults: FaultPlan,
) -> ResilientCfg {
    let dir = std::env::temp_dir().join("lmoe_fault_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_path: PathBuf = dir.join(format!("{name}.ckpt"));
    let _ = std::fs::remove_file(&ckpt_path);
    let _ = std::fs::remove_file(ckpt_path.with_extension("ckpt.prev"));
    ResilientCfg {
        dp: 2,
        batch: 1,
        seq: DIM,
        lr: 0.05,
        steps,
        save_every,
        max_restarts,
        comm_timeout: Duration::from_secs(5),
        backoff: Duration::from_millis(1),
        ckpt_path,
        faults: Arc::new(faults),
        trace: Default::default(),
    }
}

fn assert_losses_match(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.is_finite(), "loss[{i}] not finite: {x}");
        assert!(
            (x - y).abs() <= 1e-6,
            "loss[{i}] diverged: {x} vs {y}"
        );
    }
}

#[test]
fn kill_mid_run_recovers_from_checkpoint_and_matches_baseline() {
    let baseline = run_ddp_resilient(
        &cfg("kill_base", 8, 2, 0, FaultPlan::none()),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();
    assert_eq!(baseline.recoveries, 0);

    let plan = FaultPlan::new(vec![Fault::KillRank { rank: 1, step: 5 }]);
    let faulty = run_ddp_resilient(
        &cfg("kill_faulty", 8, 2, 3, plan),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();

    assert_eq!(faulty.recoveries, 1, "events: {:?}", faulty.fault_events);
    assert!(faulty
        .fault_events
        .iter()
        .any(|e| e.contains("rolled back to step 4")));
    assert_losses_match(&faulty.losses, &baseline.losses);
    // recovered params identical to the uninterrupted run's
    let pa = baseline.params.unwrap();
    let pb = faulty.params.unwrap();
    assert_eq!(pa.tensors[0].as_f32().unwrap(), pb.tensors[0].as_f32().unwrap());
    let h = faulty.health.unwrap();
    assert_eq!(h.restarts, 1);
    assert_eq!(h.comm.injected_kills, 1);
    // rank 0 replayed steps 4..8 => strictly more heartbeats than steps
    assert!(h.heartbeats[0] > 8);
}

#[test]
fn kill_without_checkpoints_restarts_from_scratch() {
    let baseline = run_ddp_resilient(
        &cfg("scratch_base", 6, 0, 0, FaultPlan::none()),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();

    let plan = FaultPlan::new(vec![Fault::KillRank { rank: 0, step: 3 }]);
    let faulty = run_ddp_resilient(
        &cfg("scratch_faulty", 6, 0, 3, plan),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();

    assert_eq!(faulty.recoveries, 1);
    assert!(faulty
        .fault_events
        .iter()
        .any(|e| e.contains("no usable checkpoint")));
    assert_losses_match(&faulty.losses, &baseline.losses);
}

#[test]
fn gives_up_after_max_restarts() {
    // Two kills at different steps; max_restarts = 1 allows surviving only
    // the first.
    let plan = FaultPlan::new(vec![
        Fault::KillRank { rank: 1, step: 2 },
        Fault::KillRank { rank: 0, step: 4 },
    ]);
    let err = run_ddp_resilient(
        &cfg("giveup", 8, 2, 1, plan),
        toy_factory(),
        toy_batches(),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("giving up"), "unexpected error: {msg}");
}

#[test]
fn corrupted_checkpoint_detected_and_run_still_completes() {
    let baseline = run_ddp_resilient(
        &cfg("crc_base", 6, 4, 0, FaultPlan::none()),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();

    // The only checkpoint (step 4) is bit-flipped on write; the kill at
    // step 5 then forces a rollback, which must *reject* the corrupt file
    // via CRC and restart from scratch rather than resume from garbage.
    let plan = FaultPlan::new(vec![
        Fault::CorruptCheckpoint { offset: 21 },
        Fault::KillRank { rank: 1, step: 5 },
    ]);
    let faulty = run_ddp_resilient(
        &cfg("crc_faulty", 6, 4, 3, plan),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();

    assert_eq!(faulty.recoveries, 1);
    assert!(
        faulty
            .fault_events
            .iter()
            .any(|e| e.contains("no usable checkpoint")),
        "events: {:?}",
        faulty.fault_events
    );
    assert_losses_match(&faulty.losses, &baseline.losses);
}

#[test]
fn delay_fault_completes_without_recovery() {
    let plan = FaultPlan::new(vec![Fault::DelayCollective {
        rank: 0,
        step: 1,
        ms: 30,
    }]);
    let report = run_ddp_resilient(
        &cfg("delay", 4, 0, 0, plan),
        toy_factory(),
        toy_batches(),
    )
    .unwrap();
    assert_eq!(report.recoveries, 0);
    let h = report.health.unwrap();
    assert_eq!(h.comm.injected_delays, 1);
    assert!(report.losses.iter().all(|l| l.is_finite()));
}
