//! Integration tests over the real artifacts: these exercise the whole
//! stack (Pallas kernels inside JAX-lowered HLO, executed via PJRT, driven
//! by the Rust coordinator).  They require `make artifacts`.

use std::sync::Arc;

use linear_moe::collectives::Comm;
use linear_moe::coordinator::ddp::{run_ddp, run_single, BatchFn, DdpConfig};
use linear_moe::coordinator::moe_ep::{ExpertWeights, MoeLayer, Strategy};
use linear_moe::coordinator::pipeline::PipelineModel;
use linear_moe::coordinator::sp::{GateKind, SpExecutor, SpMode};
use linear_moe::coordinator::{checkpoint, optimizer};
use linear_moe::data;
use linear_moe::rng::Rng;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::{Bundle, Tensor};

const DIR: &str = "artifacts";

/// Artifact gate: these tests need `make artifacts` output.  When the
/// manifest is absent (e.g. a CI box without the JAX toolchain) each test
/// skips cleanly instead of erroring, so `cargo test --test integration`
/// is safe to run unconditionally.
fn have_artifacts() -> bool {
    std::path::Path::new(DIR).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    ($name:literal) => {
        if !have_artifacts() {
            eprintln!(
                "skipping {}: no artifacts (run `make artifacts`)",
                $name
            );
            return;
        }
    };
}

fn batch_fn(vocab: usize, b: usize) -> BatchFn {
    Arc::new(move |idx: usize, n: usize| {
        let mut lm = data::ZipfLm::new(vocab, 1000 + idx as u64);
        let batch = data::batch_from_stream(&mut lm, b, n);
        (batch.tokens, batch.targets)
    })
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * x.abs().max(y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

// -------------------------------------------------------------------------
// LASP sequence parallelism == serial execution (paper Alg. 1/2), and
// LASP-1 (ring) == LASP-2 (all-gather).
// -------------------------------------------------------------------------
#[test]
fn lasp_sp_equals_serial_and_modes_agree() {
    require_artifacts!("lasp_sp_equals_serial_and_modes_agree");
    // serial reference: run the same chunks through sp_state/sp_output on
    // one rank, folding prefixes locally.
    let rt = Runtime::new(DIR).unwrap();
    for kind in [GateKind::None, GateKind::Scalar, GateKind::Vector] {
        let ex = SpExecutor::new(&rt, kind).unwrap();
        let spec = rt.manifest.artifact(&format!("sp_state_{}", kind.tag())).unwrap();
        let kshape = spec.args[0].shape.clone(); // (B,H,C,Dk)
        let (b, h, c, dk) = (kshape[0], kshape[1], kshape[2], kshape[3]);
        let t_world = 4usize;
        let mut rng = Rng::new(42);
        let mk = |rng: &mut Rng, shape: &[usize], scale: f32| {
            Tensor::f32(shape, (0..shape.iter().product::<usize>())
                .map(|_| rng.normal() * scale).collect())
        };
        // full sequence split into t_world rank chunks
        let chunks: Vec<(Tensor, Tensor, Tensor, Option<Tensor>)> = (0..t_world)
            .map(|_| {
                let q = mk(&mut rng, &[b, h, c, dk], 0.5);
                let k = mk(&mut rng, &[b, h, c, dk], 0.5);
                let v = mk(&mut rng, &[b, h, c, dk], 0.5);
                let g = match kind {
                    GateKind::None => None,
                    GateKind::Scalar => Some(Tensor::f32(
                        &[b, h, c],
                        (0..b * h * c).map(|_| 0.8 + 0.2 * rng.f32()).collect(),
                    )),
                    GateKind::Vector => Some(Tensor::f32(
                        &[b, h, c, dk],
                        (0..b * h * c * dk)
                            .map(|_| (-0.25 * rng.f32()).exp())
                            .collect(),
                    )),
                };
                (q, k, v, g)
            })
            .collect();

        // serial: fold prefix across chunks on one rank
        let mut serial_out = Vec::new();
        {
            let mut prefix = Tensor::zeros(&[b, h, dk, dk]);
            let state_exe = rt.load(&format!("sp_state_{}", kind.tag())).unwrap();
            let out_exe = rt.load(&format!("sp_output_{}", kind.tag())).unwrap();
            for (q, k, v, g) in &chunks {
                let o = match g {
                    None => out_exe.run(&[q, k, v, &prefix]).unwrap(),
                    Some(g) => out_exe.run(&[q, k, v, g, &prefix]).unwrap(),
                };
                serial_out.push(o[0].clone());
                let st = match g {
                    None => state_exe.run(&[k, v]).unwrap(),
                    Some(g) => state_exe.run(&[k, v, g]).unwrap(),
                };
                linear_moe::coordinator::sp::fold_state(&mut prefix, &st[0], &st[1])
                    .unwrap();
            }
        }
        let _ = ex;

        // parallel: t_world worker threads, both modes
        for mode in [SpMode::Lasp2AllGather, SpMode::Lasp1Ring] {
            let (_comm, handles) = Comm::new(t_world);
            let mut joins = Vec::new();
            for (rank, hdl) in handles.into_iter().enumerate() {
                let (q, k, v, g) = chunks[rank].clone();
                joins.push(std::thread::spawn(move || {
                    let rt = Runtime::new(DIR).unwrap();
                    let ex = SpExecutor::new(&rt, kind).unwrap();
                    ex.run(&hdl, mode, &q, &k, &v, g.as_ref()).unwrap()
                }));
            }
            let outs: Vec<Tensor> = joins.into_iter().map(|j| j.join().unwrap()).collect();
            for (rank, o) in outs.iter().enumerate() {
                close(
                    o.as_f32().unwrap(),
                    serial_out[rank].as_f32().unwrap(),
                    2e-4,
                    &format!("{kind:?} {mode:?} rank {rank}"),
                );
            }
        }
    }
}

// -------------------------------------------------------------------------
// LASP-2 communication volume is independent of sequence length (the
// paper's §2.2.1 claim: one d x d state per rank, nothing else).
// -------------------------------------------------------------------------
#[test]
fn lasp2_comm_volume_independent_of_chunk_content() {
    require_artifacts!("lasp2_comm_volume_independent_of_chunk_content");
    let rt = Runtime::new(DIR).unwrap();
    let spec = rt.manifest.artifact("sp_state_none").unwrap();
    let kshape = spec.args[0].shape.clone();
    let (b, h, c, dk) = (kshape[0], kshape[1], kshape[2], kshape[3]);
    drop(rt);
    let t_world = 4;
    let (comm, handles) = Comm::new(t_world);
    let mut joins = Vec::new();
    for hdl in handles {
        joins.push(std::thread::spawn(move || {
            let rt = Runtime::new(DIR).unwrap();
            let ex = SpExecutor::new(&rt, GateKind::None).unwrap();
            let mut rng = Rng::new(7 + hdl.rank as u64);
            let mk = |rng: &mut Rng, shape: &[usize]| {
                Tensor::f32(shape, (0..shape.iter().product::<usize>())
                    .map(|_| rng.normal()).collect())
            };
            let q = mk(&mut rng, &[b, h, c, dk]);
            let k = mk(&mut rng, &[b, h, c, dk]);
            let v = mk(&mut rng, &[b, h, c, dk]);
            ex.run(&hdl, SpMode::Lasp2AllGather, &q, &k, &v, None).unwrap();
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let (ag, _, _, _) = comm.traffic();
    // each rank contributes exactly (state + log_decay) floats
    let per_rank = (b * h * dk * dk + b * h * dk) * 4;
    assert_eq!(ag as usize, per_rank * t_world,
               "LASP-2 volume must be exactly one packed state per rank");
}

// -------------------------------------------------------------------------
// DDP + ZeRO-1 == single-worker training on the same global batch.
// -------------------------------------------------------------------------
#[test]
fn ddp_matches_single_worker() {
    require_artifacts!("ddp_matches_single_worker");
    let vocab = 2048;
    let steps = 3;
    let dp = 2;
    let bf = batch_fn(vocab, 2);
    let ddp = run_ddp(
        &DdpConfig {
            artifacts_dir: DIR.into(),
            tag: "tiny_gla".into(),
            batch: 2,
            seq: 128,
            dp,
            lr: 1e-3,
            steps,
            seed: 0,
        },
        bf.clone(),
    )
    .unwrap();
    // single worker with grad accumulation = dp over the same micro-batches
    let single = run_single(DIR, "tiny_gla", 2, 128, 1e-3, steps, bf, dp).unwrap();
    for (a, b) in ddp.losses.iter().zip(&single.losses) {
        assert!((a - b).abs() < 1e-4, "loss mismatch {a} vs {b}");
    }
    let (pa, _) = ddp.params.unwrap().flatten_f32().unwrap();
    let (pb, _) = single.params.unwrap().flatten_f32().unwrap();
    close(&pa, &pb, 1e-4, "ddp-vs-single params");
    assert!(ddp.traffic.0 > 0, "DDP must move gradient bytes");
}

// -------------------------------------------------------------------------
// Pipeline stage composition == monolithic fwd_bwd artifact.
// -------------------------------------------------------------------------
#[test]
fn pipeline_composition_matches_monolith() {
    require_artifacts!("pipeline_composition_matches_monolith");
    let rt = Runtime::new(DIR).unwrap();
    let tag = "tiny_gla";
    let var = rt.manifest.variant(tag).unwrap().clone();
    let params = rt.init_params(tag, 0).unwrap();

    // split the flat bundle into embed / final_norm / per-layer bundles
    // using manifest param paths.
    let specs = &var.param_specs;
    let mut embed = None;
    let mut final_norm = None;
    let mut layers: Vec<Vec<Tensor>> = vec![Vec::new(); var.config.n_layers];
    for (spec, t) in specs.iter().zip(&params.tensors) {
        if spec.path.contains("embed") {
            embed = Some(t.clone());
        } else if spec.path.contains("final_norm") {
            final_norm = Some(t.clone());
        } else {
            // path like ['layers'][i][...]
            let idx: usize = spec
                .path
                .split("['layers'][")
                .nth(1)
                .and_then(|s| s.split(']').next())
                .and_then(|s| s.parse().ok())
                .expect("layer index");
            layers[idx].push(t.clone());
        }
    }
    let embed = embed.unwrap();
    let final_norm = final_norm.unwrap();
    let layer_bundles: Vec<Bundle> = layers.into_iter().map(Bundle::new).collect();

    let mut lm = data::ZipfLm::new(var.config.vocab, 5);
    let batch = data::batch_from_stream(&mut lm, 1, 128);

    let pm = PipelineModel::new(&rt, tag, &var.config.layout, 1, 128).unwrap();
    let (ce_pipe, layer_grads, g_embed, g_fn) = pm
        .fwd_bwd(&embed, &final_norm, &layer_bundles, &batch.tokens, &batch.targets)
        .unwrap();

    // monolith
    let exe = rt.load("fwd_bwd_tiny_gla_b1n128").unwrap();
    let out = exe
        .run_bundled(&[&params], &[&batch.tokens, &batch.targets])
        .unwrap();
    let ce_mono = out[1].item_f32().unwrap();
    assert!((ce_pipe - ce_mono).abs() < 1e-4, "{ce_pipe} vs {ce_mono}");
    let grads = &out[2..2 + params.tensors.len()];

    // compare grads leaf by leaf using the same path split
    let mut gi = 0usize;
    let mut layer_leaf = vec![0usize; var.config.n_layers];
    for spec in specs.iter() {
        let got: &Tensor;
        if spec.path.contains("embed") {
            got = &g_embed;
        } else if spec.path.contains("final_norm") {
            got = &g_fn;
        } else {
            let idx: usize = spec
                .path
                .split("['layers'][")
                .nth(1)
                .and_then(|s| s.split(']').next())
                .and_then(|s| s.parse().ok())
                .unwrap();
            got = &layer_grads[idx].tensors[layer_leaf[idx]];
            layer_leaf[idx] += 1;
        }
        close(
            got.as_f32().unwrap(),
            grads[gi].as_f32().unwrap(),
            3e-3,
            &format!("grad {}", spec.path),
        );
        gi += 1;
    }
}

// -------------------------------------------------------------------------
// MoE execution strategies agree and differ in launch count.
// -------------------------------------------------------------------------
#[test]
fn moe_strategies_agree_numerically() {
    require_artifacts!("moe_strategies_agree_numerically");
    let rt = Runtime::new(DIR).unwrap();
    let layer = MoeLayer::new(&rt, "tiny").unwrap();
    let mut rng = Rng::new(11);
    let weights = ExpertWeights::random(&mut rng, layer.n_experts, layer.d, 128);
    let spec = rt.manifest.artifact("moe_router_tiny").unwrap();
    let t = spec.args[1].shape[0];
    let router_w = Tensor::f32(
        &[layer.d, layer.n_experts],
        (0..layer.d * layer.n_experts).map(|_| rng.normal() * 0.02).collect(),
    );
    let x = Tensor::f32(
        &[t, layer.d],
        (0..t * layer.d).map(|_| rng.normal() * 0.5).collect(),
    );
    let (y_loop, counts, l_loop) = layer
        .forward_local(Strategy::Loop, &router_w, &weights, &x)
        .unwrap();
    let (y_grp, _, l_grp) = layer
        .forward_local(Strategy::Grouped, &router_w, &weights, &x)
        .unwrap();
    let (y_mb, _, l_mb) = layer
        .forward_local(Strategy::MegaBlocks, &router_w, &weights, &x)
        .unwrap();
    close(y_loop.as_f32().unwrap(), y_grp.as_f32().unwrap(), 1e-4, "loop-vs-grouped");
    close(y_loop.as_f32().unwrap(), y_mb.as_f32().unwrap(), 1e-4, "loop-vs-megablocks");
    assert_eq!(l_loop, layer.n_experts);
    assert_eq!(l_grp, 1);
    // exact-fit tiles: sum of ceil(count/tile)
    let want_mb: usize = counts.iter().map(|&c| c.div_ceil(layer.tile)).sum();
    assert_eq!(l_mb, want_mb);
}

// -------------------------------------------------------------------------
// HLO Adam == Rust Adam.
// -------------------------------------------------------------------------
#[test]
fn hlo_adam_matches_rust_adam() {
    require_artifacts!("hlo_adam_matches_rust_adam");
    let rt = Runtime::new(DIR).unwrap();
    let hlo = optimizer::HloAdam::new(&rt, 4096).unwrap();
    let n = 6000; // crosses a bucket boundary
    let mut rng = Rng::new(3);
    let mut p1: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let mut m1 = vec![0f32; n];
    let mut v1 = vec![0f32; n];
    let mut p2 = p1.clone();
    let mut m2 = m1.clone();
    let mut v2 = v1.clone();
    for step in 1..=3 {
        optimizer::adam_step_flat(&mut p1, &g, &mut m1, &mut v1, step, 1e-2);
        hlo.step_flat(&mut p2, &g, &mut m2, &mut v2, step, 1e-2).unwrap();
    }
    close(&p1, &p2, 1e-5, "adam params");
    close(&m1, &m2, 1e-6, "adam m");
    close(&v1, &v2, 1e-6, "adam v");
}

// -------------------------------------------------------------------------
// Checkpoint roundtrip through a real parameter bundle + resume.
// -------------------------------------------------------------------------
#[test]
fn checkpoint_roundtrip_with_real_params() {
    require_artifacts!("checkpoint_roundtrip_with_real_params");
    let rt = Runtime::new(DIR).unwrap();
    let params = rt.init_params("tiny_bla", 0).unwrap();
    let dir = std::env::temp_dir().join("lmoe_int_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.ckpt");
    checkpoint::save(&path, &[("params", &params)]).unwrap();
    let loaded = checkpoint::load(&path).unwrap();
    assert_eq!(loaded[0].1.numel(), params.numel());
    let (a, _) = params.flatten_f32().unwrap();
    let (b, _) = loaded[0].1.flatten_f32().unwrap();
    assert_eq!(a, b);
}

// -------------------------------------------------------------------------
// Variable-length handling (paper §2.2.4): packed batches train on more
// real tokens than padded batches for the same compute shape.
// -------------------------------------------------------------------------
#[test]
fn packing_yields_more_real_tokens_and_finite_loss() {
    require_artifacts!("packing_yields_more_real_tokens_and_finite_loss");
    let rt = Runtime::new(DIR).unwrap();
    let exe = rt.load("eval_loss_tiny_gla_b2n128").unwrap();
    let params = rt.init_params("tiny_gla", 0).unwrap();
    let mut lm = data::ZipfLm::new(2048, 9);
    let mut rng = Rng::new(10);
    let lens = data::sample_doc_lengths(&mut rng, 32, 40, 128);
    let docs: Vec<Vec<i32>> = lens.iter().map(|&l| lm.document(l)).collect();
    let padded = data::batch_padded(&docs, 2, 128, 0);
    let (packed, _) = data::batch_packed(&docs, 2, 128);
    assert!(packed.real_tokens > padded.real_tokens);
    for b in [&padded, &packed] {
        let out = exe.run_bundled(&[&params], &[&b.tokens, &b.targets]).unwrap();
        let ce = out[1].item_f32().unwrap();
        assert!(ce.is_finite() && ce > 0.0);
    }
}
