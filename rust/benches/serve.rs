//! Serving bench: LSM constant-state vs attention KV-staircase (Fig. 5,
//! in serving form), on the artifact-free reference backends.
//!
//! Part 1 -- swap cost vs position: advance a single lane to position P,
//! then time a save_lane + load_lane roundtrip.  The LSM session is a
//! fixed d-vector, so bytes and time are flat in P; the attention session
//! is the power-of-two KV staircase, so both climb.
//!
//! Part 2 -- engine throughput: the same deterministic Poisson-ish trace
//! through a 4-lane continuous-batching engine on each backend, with a
//! preemption quantum so state swapping is actually exercised.  Records
//! BENCH_serve.json (override the path with BENCH_JSON_OUT) and
//! schema-checks it by re-reading.  SERVE_SMOKE=1 shrinks everything for
//! a CI smoke run.
//!
//! Part 3 -- fault-rate sweep: the LSM engine under seeded step-error
//! storms at 0%, 1%, and 5% per decode attempt, measuring what fault
//! supervision costs: goodput (finished-request tokens/sec), replays, and
//! how many requests survive vs fail.  Same trace at every rate (fault
//! coordinates are rate-invariant), so rows are directly comparable.

use std::sync::Arc;

use linear_moe::bench_util::bench;
use linear_moe::coordinator::metrics::{Summary, Table};
use linear_moe::inference::{Decoder, LaneState};
use linear_moe::json::{self, Json};
use linear_moe::rng::Rng;
use linear_moe::serve::{
    poisson_trace, Engine, EngineCfg, FaultDecoder, RefAttnDecoder, RefLsmDecoder,
    Request, Sampling, ServeFaultPlan, ServeReport,
};
use linear_moe::tensor::Tensor;

const VOCAB: usize = 64;
const SEED: u64 = 11;

/// Feed `pos` tokens into lane 0 so the session reaches that position.
fn advance<D: Decoder>(dec: &mut D, pos: usize) -> anyhow::Result<()> {
    dec.reset_lane(0)?;
    for p in 0..pos {
        let tok = (p % VOCAB) as i32;
        dec.decode_step(&Tensor::i32(&[1], vec![tok]), &[p as i32])?;
    }
    Ok(())
}

struct SwapRow {
    backend: &'static str,
    pos: usize,
    state_bytes: usize,
    swap_us: f64,
}

fn swap_cost<D: Decoder>(
    name: &'static str,
    mut dec: D,
    positions: &[usize],
    iters: usize,
) -> anyhow::Result<Vec<SwapRow>> {
    let mut rows = Vec::new();
    for &pos in positions {
        advance(&mut dec, pos)?;
        let mut st = LaneState::default();
        dec.save_lane(0, &mut st)?; // size the buffers once
        let r = bench(&format!("{name} swap @pos {pos}"), 2, iters, || {
            dec.save_lane(0, &mut st).unwrap();
            dec.load_lane(0, &st).unwrap();
        });
        rows.push(SwapRow {
            backend: name,
            pos,
            state_bytes: dec.lane_state_bytes(pos),
            swap_us: r.median_ms * 1e3,
        });
    }
    Ok(rows)
}

/// Key/value shorthand for the `Json::obj` rows below.
fn kv(k: &str, v: impl Into<Json>) -> (String, Json) {
    (k.to_string(), v.into())
}

fn serve_requests(n: usize) -> Vec<Request> {
    let mut rng = Rng::new(SEED ^ 0x5157);
    let prompt_len = 6;
    (0..n as u64)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len).map(|_| rng.below(VOCAB) as i32).collect(),
            max_new: 8 + rng.below(17),
            eos: None,
            sampling: Sampling::Greedy,
            seed: id,
            ttl: None,
        })
        .collect()
}

fn run_engine<D: Decoder>(dec: D, reqs: &[Request]) -> anyhow::Result<ServeReport> {
    run_engine_cfg(
        dec,
        reqs,
        EngineCfg { preempt_after: Some(4), ..Default::default() },
    )
}

fn run_engine_cfg<D: Decoder>(
    dec: D,
    reqs: &[Request],
    cfg: EngineCfg,
) -> anyhow::Result<ServeReport> {
    let mut rng = Rng::new(SEED);
    let trace = poisson_trace(&mut rng, reqs.len(), 2.0, |id| reqs[id as usize].clone());
    Engine::new(dec, cfg)?.run_trace(&trace)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("SERVE_SMOKE").is_ok();
    let iters: usize = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 4 } else { 64 });

    // --- Part 1: state swap cost vs decode position --------------------
    let positions: Vec<usize> =
        if smoke { vec![16, 32, 64] } else { vec![64, 128, 256, 512, 1024] };
    let d = if smoke { 16 } else { 64 };
    let mut swap_rows = swap_cost(
        "lsm",
        RefLsmDecoder::new(1, VOCAB, d, SEED),
        &positions,
        iters,
    )?;
    swap_rows.extend(swap_cost(
        "attn",
        RefAttnDecoder::new(1, VOCAB, d, 16, SEED),
        &positions,
        iters,
    )?);

    let lsm_bytes: Vec<usize> = swap_rows
        .iter()
        .filter(|r| r.backend == "lsm")
        .map(|r| r.state_bytes)
        .collect();
    let attn_bytes: Vec<usize> = swap_rows
        .iter()
        .filter(|r| r.backend == "attn")
        .map(|r| r.state_bytes)
        .collect();
    assert!(
        lsm_bytes.windows(2).all(|w| w[0] == w[1]),
        "LSM session bytes must be flat in position: {lsm_bytes:?}"
    );
    assert!(
        attn_bytes.windows(2).all(|w| w[0] <= w[1])
            && attn_bytes.last() > attn_bytes.first(),
        "attention KV staircase must grow with position: {attn_bytes:?}"
    );

    let mut table = Table::new(&["swap", "pos", "state bytes", "median us"]);
    for r in &swap_rows {
        table.row(&[
            r.backend.to_string(),
            r.pos.to_string(),
            r.state_bytes.to_string(),
            format!("{:.2}", r.swap_us),
        ]);
    }
    println!("\n=== Session swap cost vs position (d={d}) ===");
    table.print();

    // --- Part 2: engine throughput on the same trace -------------------
    let n = if smoke { 16 } else { 64 };
    let reqs = serve_requests(n);
    let mut engine_rows: Vec<Json> = Vec::new();
    let mut table = Table::new(&[
        "engine", "tok/s", "occupancy", "swaps", "swap MiB", "reallocs",
        "p50 wait", "p95 ttft", "p99 ttft",
    ]);
    let runs: Vec<(&str, ServeReport)> = vec![
        ("lsm", run_engine(RefLsmDecoder::new(4, VOCAB, d, SEED), &reqs)?),
        ("attn", run_engine(RefAttnDecoder::new(4, VOCAB, d, 16, SEED), &reqs)?),
    ];
    for (name, rep) in &runs {
        assert_eq!(rep.results.len(), n, "{name}: all requests must finish");
        assert!(rep.outcomes.all_finished(), "{name}: clean run, no faults");
        let waits: Vec<f64> = rep
            .results
            .iter()
            .filter_map(|r| r.queue_wait().map(|w| w as f64))
            .collect();
        let ttfts: Vec<f64> = rep
            .results
            .iter()
            .filter_map(|r| r.ttft().map(|t| t as f64))
            .collect();
        let (w, t) = (Summary::of(&waits), Summary::of(&ttfts));
        table.row(&[
            name.to_string(),
            format!("{:.0}", rep.tokens_per_sec()),
            format!("{:.2}", rep.occupancy()),
            rep.swaps.to_string(),
            format!("{:.3}", rep.swap_bytes as f64 / (1024.0 * 1024.0)),
            rep.state_reallocs.to_string(),
            format!("{:.0}", w.p50),
            format!("{:.0}", t.p95),
            format!("{:.0}", t.p99),
        ]);
        engine_rows.push(Json::obj([
            kv("backend", *name),
            kv("requests", n),
            kv("lanes", 4u64),
            kv("tokens_out", rep.tokens_out),
            kv("tokens_per_sec", rep.tokens_per_sec()),
            kv("occupancy", rep.occupancy()),
            kv("steps", rep.steps),
            kv("swaps", rep.swaps),
            kv("swap_bytes", rep.swap_bytes),
            kv("state_reallocs", rep.state_reallocs),
            kv("queue_wait_p50_ticks", w.p50),
            kv("ttft_min_ticks", t.min),
            kv("ttft_p95_ticks", t.p95),
            kv("ttft_p99_ticks", t.p99),
        ]));
    }
    println!("\n=== Continuous-batching engine, {n} requests, 4 lanes ===");
    table.print();

    // the Fig. 5 contrast: same trace, same swap count regime, but the
    // attention engine moves far more state per swap
    let (lsm_rep, attn_rep) = (&runs[0].1, &runs[1].1);
    if lsm_rep.swaps > 0 && attn_rep.swaps > 0 {
        assert!(
            attn_rep.swap_bytes / attn_rep.swaps
                > lsm_rep.swap_bytes / lsm_rep.swaps,
            "KV staircase must cost more bytes per swap than constant state"
        );
    }

    // --- Part 3: fault-rate sweep on the LSM engine --------------------
    // same trace at every rate; seeded step errors are rate-invariant in
    // their coordinates, so the 1% storm is a subset of the 5% one
    let rates = [0.0, 0.01, 0.05];
    let horizon = 2000; // covers every decode attempt either trace makes
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut table = Table::new(&[
        "fault rate", "injected", "finished", "failed", "recovered", "retries",
        "goodput tok/s",
    ]);
    for &rate in &rates {
        let plan =
            Arc::new(ServeFaultPlan::seeded_step_errors(SEED ^ 0xFA017, horizon, 4, rate));
        let cfg = EngineCfg {
            preempt_after: Some(4),
            fault: plan.clone(),
            ..Default::default()
        };
        let dec = FaultDecoder::new(RefLsmDecoder::new(4, VOCAB, d, SEED), plan);
        let rep = run_engine_cfg(dec, &reqs, cfg)?;
        let o = rep.outcomes;
        let retries: u64 = rep.results.iter().map(|r| r.retries as u64).sum();
        assert_eq!(o.total(), n as u64, "rate {rate}: every request accounted for");
        if rate == 0.0 {
            assert_eq!(rep.faults_injected, 0, "empty plan injects nothing");
            assert!(o.all_finished(), "clean sweep baseline");
        } else if rate >= 0.05 {
            assert!(rep.faults_injected > 0, "5% storm must fire on this trace");
        }
        table.row(&[
            format!("{:.0}%", rate * 100.0),
            rep.faults_injected.to_string(),
            o.finished.to_string(),
            o.failed.to_string(),
            o.recovered.to_string(),
            retries.to_string(),
            format!("{:.0}", rep.tokens_per_sec()),
        ]);
        sweep_rows.push(Json::obj([
            kv("rate", rate),
            kv("faults_injected", rep.faults_injected),
            kv("finished", o.finished),
            kv("failed", o.failed),
            kv("recovered", o.recovered),
            kv("retries", retries),
            kv("steps", rep.steps),
            kv("tokens_out", rep.tokens_out),
            kv("goodput_tok_s", rep.tokens_per_sec()),
        ]));
    }
    println!("\n=== Fault-rate sweep, LSM engine, {n} requests, 4 lanes ===");
    table.print();

    // --- Emit + schema-check BENCH_serve.json --------------------------
    let out = std::env::var("BENCH_JSON_OUT")
        .unwrap_or_else(|_| "../BENCH_serve.json".to_string());
    let swap_json: Vec<Json> = swap_rows
        .iter()
        .map(|r| {
            Json::obj([
                kv("backend", r.backend),
                kv("pos", r.pos),
                kv("state_bytes", r.state_bytes),
                kv("swap_us", r.swap_us),
            ])
        })
        .collect();
    let doc = Json::obj([
        kv("bench", "serve"),
        kv("smoke", smoke),
        kv("iters", iters),
        kv("d", d),
        ("swap_cost".to_string(), Json::Arr(swap_json)),
        ("engine".to_string(), Json::Arr(engine_rows)),
        ("fault_sweep".to_string(), Json::Arr(sweep_rows)),
    ]);
    std::fs::write(&out, doc.pretty())?;
    println!("wrote {out}");

    let parsed = json::parse(&std::fs::read_to_string(&out)?)?;
    assert_eq!(parsed.str_field("bench")?, "serve");
    let swap = parsed.get("swap_cost").and_then(|v| v.as_arr()).expect("swap_cost");
    assert_eq!(swap.len(), 2 * positions.len());
    for row in swap {
        row.str_field("backend")?;
        row.usize_field("pos")?;
        row.usize_field("state_bytes")?;
        assert!(row.get("swap_us").and_then(|v| v.as_f64()).is_some());
    }
    let eng = parsed.get("engine").and_then(|v| v.as_arr()).expect("engine");
    assert_eq!(eng.len(), 2);
    for row in eng {
        row.str_field("backend")?;
        row.usize_field("tokens_out")?;
        row.usize_field("swaps")?;
        assert!(row.get("tokens_per_sec").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("occupancy").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("ttft_min_ticks").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("ttft_p95_ticks").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("ttft_p99_ticks").and_then(|v| v.as_f64()).is_some());
    }
    let sweep = parsed.get("fault_sweep").and_then(|v| v.as_arr()).expect("fault_sweep");
    assert_eq!(sweep.len(), rates.len());
    for row in sweep {
        assert!(row.get("rate").and_then(|v| v.as_f64()).is_some());
        row.usize_field("faults_injected")?;
        row.usize_field("finished")?;
        row.usize_field("failed")?;
        row.usize_field("recovered")?;
        row.usize_field("retries")?;
        assert!(row.get("goodput_tok_s").and_then(|v| v.as_f64()).is_some());
    }
    println!("schema check passed");
    Ok(())
}
