//! Collectives micro-bench: latency of all-gather / all-reduce /
//! all-to-all vs payload size and world size (the substrate under every
//! distributed number in the other benches).

use linear_moe::collectives::Comm;
use linear_moe::coordinator::metrics::Table;
use linear_moe::tensor::Tensor;

fn main() {
    let iters: usize = std::env::var("BENCH_ITERS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(50);
    let mut table = Table::new(&["op", "world", "elems", "us/op"]);
    for world in [2usize, 4, 8] {
        for numel in [1024usize, 65536] {
            for op in ["all_gather", "all_reduce", "all_to_all"] {
                let (_c, handles) = Comm::new(world);
                let t0 = std::time::Instant::now();
                let joins: Vec<_> = handles.into_iter().map(|h| {
                    let op = op.to_string();
                    std::thread::spawn(move || {
                        for _ in 0..iters {
                            match op.as_str() {
                                "all_gather" => {
                                    h.all_gather(Tensor::zeros(&[numel])).unwrap();
                                }
                                "all_reduce" => {
                                    h.all_reduce_sum(Tensor::zeros(&[numel])).unwrap();
                                }
                                _ => {
                                    let parts = (0..h.world)
                                        .map(|_| Tensor::zeros(&[numel / h.world]))
                                        .collect();
                                    h.all_to_all(parts).unwrap();
                                }
                            }
                        }
                    })
                }).collect();
                for j in joins { j.join().unwrap(); }
                let us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
                table.row(&[op.to_string(), world.to_string(),
                            numel.to_string(), format!("{us:.0}")]);
            }
        }
    }
    println!("\n=== collectives micro-bench ===");
    table.print();
}
