//! Paper Table 3 + Fig. 4: training memory (GB, modeled) and throughput
//! (tokens/s, measured) vs sequence length at fixed tokens/iter.
//! Paper: seq {2K..16K} x batch {8..1} on 8xA100; here seq {256..2048} x
//! batch {8..1} (fixed 2048 tokens/iter) on the CPU-PJRT testbed.
//! The claim under test is the *shape*: Baseline throughput decays with N
//! and its memory grows; LSM instances stay flat.

use linear_moe::coordinator::metrics::{Table, Throughput};
use linear_moe::data;
use linear_moe::memcost;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;

const SHAPES: &[(usize, usize)] = &[(8, 256), (4, 512), (2, 1024), (1, 2048)];
const INSTANCES: &[&str] = &[
    "tiny_attn", "tiny_bla", "tiny_retention", "tiny_gla", "tiny_deltanet",
    "tiny_mamba2", "tiny_hgrn2", "tiny_rwkv6",
];

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("BENCH_ITERS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(3);
    let rt = Runtime::new("artifacts")?;
    let mut table = Table::new(&[
        "instance", "seq x batch", "mem MiB (model)", "thpt tok/s", "ms/iter",
    ]);
    for tag in INSTANCES {
        let var = rt.manifest.variant(tag)?.clone();
        for &(b, n) in SHAPES {
            let name = format!("train_step_{tag}_b{b}n{n}");
            let exe = rt.load(&name)?;
            let mut params = rt.init_params(tag, 0)?;
            let m = params.zeros_like();
            let v = params.zeros_like();
            let mut lm = data::ZipfLm::new(var.config.vocab, 3);
            let batch = data::batch_from_stream(&mut lm, b, n);
            let lr = Tensor::scalar_f32(1e-3);
            let step_t = Tensor::scalar_i32(1);
            let mut thpt = Throughput::new(b * n, 1);
            thpt.start();
            for _ in 0..iters + 1 {
                let out = exe.run_bundled(&[&params, &m, &v],
                                          &[&step_t, &lr, &batch.tokens, &batch.targets])?;
                std::hint::black_box(out[0].item_f32()?);
                thpt.lap();
            }
            // memory: modeled (paper uses A100 GB; flash=false for the
            // standard-attention Baseline, true/flat for LSM rows)
            let flash = var.config.layout.chars().all(|c| c == 'L');
            let mem = memcost::train_bytes(
                &var.config, b, n, &memcost::ParallelCfg::single(), flash);
            table.row(&[
                tag.to_string(),
                format!("{n}x{b}"),
                format!("{:.1}", memcost::mib(mem)),
                format!("{:.0}", thpt.tokens_per_sec()),
                format!("{:.0}", thpt.mean_ms()),
            ]);
            let _ = &mut params;
        }
    }
    println!("\n=== Table 3 / Fig 4: training efficiency (fixed 2048 tokens/iter) ===");
    table.print();
    Ok(())
}
