//! Paper Fig. 5: inference latency + memory vs decode length.
//! Linear-MoE (BLA) decodes with a constant-size state; the attention
//! Baseline's KV cache (power-of-two staircase) grows, so per-token
//! latency and memory climb with position.

use linear_moe::coordinator::metrics::Table;
use linear_moe::inference::{greedy, AttnDecoder, LsmDecoder};
use linear_moe::memcost;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let max_len: usize = std::env::var("BENCH_DECODE_LEN").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(2048);
    let sizes: Vec<usize> = [128usize, 256, 512, 1024, 2048, 4096]
        .into_iter().filter(|&n| n <= max_len.max(128)).collect();
    let rt = Runtime::new("artifacts")?;
    let batch = 4;
    let mut lsm = LsmDecoder::new(&rt, "tiny_bla", batch)?;
    let mut attn = AttnDecoder::new(&rt, "tiny_attn", batch, &sizes)?;
    let lsm_cfg = lsm.var.config.clone();
    let attn_cfg = attn.var.config.clone();

    let mut table = Table::new(&[
        "decode len", "BLA ms/tok", "BLA state KiB", "Attn ms/tok", "KV KiB",
    ]);
    let mut tok_l = Tensor::i32(&[batch], vec![1; batch]);
    let mut tok_a = tok_l.clone();
    let mut pos = 0usize;
    for &seg_end in &sizes {
        let seg = seg_end - pos;
        let t0 = std::time::Instant::now();
        for p in pos..seg_end {
            let lg = lsm.step(&tok_l, p as i32)?;
            tok_l = greedy(&lg)?;
        }
        let lsm_ms = t0.elapsed().as_secs_f64() * 1e3 / seg as f64;
        let t1 = std::time::Instant::now();
        for p in pos..seg_end {
            let lg = attn.step(&tok_a, p as i32)?;
            tok_a = greedy(&lg)?;
        }
        let attn_ms = t1.elapsed().as_secs_f64() * 1e3 / seg as f64;
        pos = seg_end;
        table.row(&[
            seg_end.to_string(),
            format!("{lsm_ms:.2}"),
            format!("{:.0}", memcost::decode_state_bytes(&lsm_cfg, batch, seg_end) as f64 / 1024.0),
            format!("{attn_ms:.2}"),
            format!("{:.0}", memcost::decode_state_bytes(&attn_cfg, batch, seg_end) as f64 / 1024.0),
        ]);
        if pos >= max_len { break; }
    }
    println!("\n=== Fig 5: decode latency/memory vs length (batch {batch}) ===");
    table.print();
    println!("(measured state: BLA {} KiB constant; attn staircase grows)",
             lsm.state_bytes() / 1024);
    Ok(())
}
