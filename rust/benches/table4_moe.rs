//! Paper Table 4 (top): MoE optimization ablation, plus the
//! expert-parallel overlap bench.
//!
//! Part 1 (needs compiled artifacts): baseline loop-over-experts vs
//! GroupedGEMM vs MegaBlocks-style exact-fit tiles on the PJRT backend.
//! Skipped with a notice when no artifact manifest is present.
//!
//! Part 2 (always runs, pure-Rust reference backend): the chunked,
//! overlapped EP pipeline vs the sequential dispatch->compute->combine
//! baseline over ep_world ∈ {1, 2, 4}.  Per-(rank, round) expert load is
//! deliberately imbalanced -- that is the regime where FSMoE-style
//! pipelining pays: sequential pays the max load every round, overlapped
//! pays each rank's own sum.  Asserts EP outputs are bit-identical to the
//! single-rank reference and that the dispatch arena stops allocating
//! after warmup, then records BENCH_moe_ep.json (override the path with
//! BENCH_JSON_OUT).  EP_SMOKE=1 shrinks shapes for a CI smoke run and
//! skips the wall-clock assertion.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use linear_moe::bench_util::bench;
use linear_moe::collectives::Comm;
use linear_moe::coordinator::metrics::Table;
use linear_moe::coordinator::moe_ep::{
    forward_ep, forward_tokens, DispatchArena, EpCfg, ExpertWeights, MoeGeom,
    MoeLayer, ReferenceExperts, Strategy,
};
use linear_moe::json::{self, Json};
use linear_moe::rng::Rng;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;

struct EpShape {
    d: usize,
    f: usize,
    n_experts: usize,
    heavy: usize,
    light: usize,
}

struct Batch {
    geom: MoeGeom,
    weights: ExpertWeights,
    xv: Vec<f32>,
    gates: Vec<f32>,
    idx: Vec<i32>,
    t: usize,
}

/// Routing with a deliberately imbalanced per-(rank, round) load: expert
/// (q, c) is heavy iff (q + c) % world == 0, so with chunk=1 every round
/// has exactly one busy rank.  Totals are world-divisible so tokens
/// partition evenly across EP ranks.
fn crafted_batch(rng: &mut Rng, shape: &EpShape, world: usize) -> Batch {
    let epr = shape.n_experts / world;
    let mut idx = Vec::new();
    for q in 0..world {
        for c in 0..epr {
            let n = if (q + c) % world == 0 { shape.heavy } else { shape.light };
            for _ in 0..n {
                idx.push((q * epr + c) as i32);
            }
        }
    }
    let t = idx.len();
    assert_eq!(t % world, 0, "crafted load must partition across ranks");
    let gates: Vec<f32> = (0..t).map(|_| rng.f32()).collect();
    let xv: Vec<f32> = (0..t * shape.d).map(|_| rng.normal()).collect();
    let weights = ExpertWeights::random(rng, shape.n_experts, shape.d, shape.f);
    let geom = MoeGeom {
        d: shape.d,
        n_experts: shape.n_experts,
        top_k: 1,
        cap: shape.heavy, // generous: no drops
        tile: 8,
    };
    Batch { geom, weights, xv, gates, idx, t }
}

/// (rank, wall, compute, overlapped, launches, rounds, local output)
type RankOut = (usize, Duration, Duration, Duration, usize, usize, Vec<f32>);

struct EpRun {
    ms_per_iter: f64,
    overlap_frac: f64,
    launches: usize,
    a2a_bytes: u64,
    a2a_ops: u64,
    rounds: usize,
}

/// SPMD-run `iters` EP forwards over `world` threads, barrier-aligned, and
/// return the slowest rank's per-iter wall clock.  Also verifies the
/// dispatch arena allocates nothing after the warmup forward.
fn run_ep_bench(
    b: &Batch,
    world: usize,
    cfg: EpCfg,
    iters: usize,
) -> anyhow::Result<(EpRun, Vec<f32>)> {
    let (t, geom) = (b.t, b.geom);
    let t_local = t / world;
    let backend0 = ReferenceExperts::new(b.weights.clone());
    let (comm, handles) = Comm::new(world);
    let shared = Arc::new((b.xv.clone(), b.gates.clone(), b.idx.clone()));
    let joins: Vec<_> = handles
        .into_iter()
        .map(|h| {
            let backend = backend0.clone();
            let shared = shared.clone();
            thread::spawn(move || -> anyhow::Result<RankOut> {
                let (xv, gates, idx) = &*shared;
                let (r, d, k) = (h.rank, geom.d, geom.top_k);
                let x = Tensor::f32(
                    &[t_local, d],
                    xv[r * t_local * d..(r + 1) * t_local * d].to_vec(),
                );
                let g = &gates[r * t_local * k..(r + 1) * t_local * k];
                let i = &idx[r * t_local * k..(r + 1) * t_local * k];
                let mut arena = DispatchArena::new();
                // warmup sizes the arena lanes
                let (y, _) = forward_ep(&h, &backend, &cfg, &geom, g, i, &x, &mut arena)?;
                let warm_allocs = arena.alloc_events();
                h.barrier()?;
                let t0 = Instant::now();
                let mut compute = Duration::ZERO;
                let mut overlapped = Duration::ZERO;
                let mut launches = 0usize;
                let mut rounds = 0usize;
                for _ in 0..iters {
                    let (_, s) =
                        forward_ep(&h, &backend, &cfg, &geom, g, i, &x, &mut arena)?;
                    compute += s.compute;
                    overlapped += s.compute_overlapped;
                    launches += s.launches;
                    rounds = s.rounds;
                }
                h.barrier()?;
                let dt = t0.elapsed();
                anyhow::ensure!(
                    arena.alloc_events() == warm_allocs,
                    "rank {r}: dispatch arena grew after warmup \
                     ({} -> {} alloc events)",
                    warm_allocs,
                    arena.alloc_events()
                );
                Ok((r, dt, compute, overlapped, launches, rounds, y.as_f32()?.to_vec()))
            })
        })
        .collect();
    let mut y_global = vec![0f32; t * geom.d];
    let mut slowest = Duration::ZERO;
    let mut compute = Duration::ZERO;
    let mut overlapped = Duration::ZERO;
    let mut launches = 0usize;
    let mut rounds = 0usize;
    for j in joins {
        let (r, dt, c, o, l, rd, y) = j.join().expect("EP bench rank panicked")?;
        slowest = slowest.max(dt);
        compute += c;
        overlapped += o;
        launches += l;
        rounds = rd;
        y_global[r * t_local * geom.d..(r + 1) * t_local * geom.d].copy_from_slice(&y);
    }
    let traffic = comm.traffic_by_kind();
    Ok((
        EpRun {
            ms_per_iter: slowest.as_secs_f64() * 1e3 / iters as f64,
            overlap_frac: if compute.as_secs_f64() > 0.0 {
                overlapped.as_secs_f64() / compute.as_secs_f64()
            } else {
                0.0
            },
            launches: launches / iters.max(1),
            a2a_bytes: traffic.all_to_all_bytes,
            a2a_ops: traffic.all_to_all_ops,
            rounds,
        },
        y_global,
    ))
}

fn part1_artifacts(iters: usize) -> anyhow::Result<()> {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(_) => {
            println!("(no artifact manifest; skipping PJRT strategy table)");
            return Ok(());
        }
    };
    let layer = match MoeLayer::new(&rt, "bench") {
        Ok(l) => l,
        Err(_) => {
            println!("(no MoE bench artifacts; skipping PJRT strategy table)");
            return Ok(());
        }
    };
    let mut table = Table::new(&[
        "MoE execution", "time/iter ms", "launches", "padded slots",
    ]);
    let mut rng = Rng::new(5);
    let f_dim = 256;
    let weights = ExpertWeights::random(&mut rng, layer.n_experts, layer.d, f_dim);
    let t = rt.manifest.artifact("moe_router_bench")?.args[1].shape[0];
    let router_w = Tensor::f32(&[layer.d, layer.n_experts],
        (0..layer.d * layer.n_experts).map(|_| rng.normal() * 0.02).collect());
    let x = Tensor::f32(&[t, layer.d],
        (0..t * layer.d).map(|_| rng.normal() * 0.5).collect());

    for (name, strat) in [("Baseline (loop)", Strategy::Loop),
                          ("Grouped GEMM", Strategy::Grouped),
                          ("MegaBlocks (tiles)", Strategy::MegaBlocks)] {
        let (_, counts, launches) =
            layer.forward_local(strat, &router_w, &weights, &x)?;
        let padded: usize = match strat {
            Strategy::Loop | Strategy::Grouped => counts.iter()
                .map(|&c| layer.cap.saturating_sub(c.min(layer.cap))).sum(),
            Strategy::MegaBlocks => counts.iter()
                .map(|&c| c.div_ceil(layer.tile) * layer.tile - c).sum(),
        };
        // arena + bound backend reused across iters: steady-state timing
        let mut arena = DispatchArena::new();
        let r = bench(name, 2, iters, || {
            let _ = layer
                .forward_local_with(strat, &router_w, &weights, &x, &mut arena)
                .unwrap();
        });
        table.row(&[name.to_string(), format!("{:.1}", r.mean_ms),
                    launches.to_string(), padded.to_string()]);
    }
    println!("\n=== Table 4 (top): MoE optimization ({t} tokens, {} experts) ===",
             layer.n_experts);
    table.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("EP_SMOKE").is_ok();
    let iters: usize = std::env::var("BENCH_ITERS").ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 2 } else { 8 });

    part1_artifacts(iters)?;

    // --- Part 2: expert-parallel overlap (reference backend, no artifacts)
    let shape = if smoke {
        EpShape { d: 8, f: 8, n_experts: 8, heavy: 8, light: 4 }
    } else {
        EpShape { d: 64, f: 128, n_experts: 8, heavy: 64, light: 4 }
    };
    let mut rng = Rng::new(17);
    let mut table = Table::new(&[
        "EP config", "time/iter ms", "overlap %", "launches", "a2a MiB", "speedup",
    ]);
    let kv = |k: &str, v: Json| (k.to_string(), v);
    let mut json_rows: Vec<Json> = Vec::new();
    for world in [1usize, 2, 4] {
        let b = crafted_batch(&mut rng, &shape, world);
        // bit-identical reference over the concatenated batch
        let backend = ReferenceExperts::new(b.weights.clone());
        let mut arena = DispatchArena::new();
        let (y_ref, _, _, _) = forward_tokens(
            &backend, Strategy::MegaBlocks, &b.geom, &b.gates, &b.idx, &b.xv, b.t,
            &mut arena,
        )?;
        let mut seq_ms = 0.0f64;
        for overlap in [false, true] {
            let cfg = EpCfg { strategy: Strategy::MegaBlocks, chunk: 1, overlap };
            let (run, y_ep) = run_ep_bench(&b, world, cfg, iters)?;
            assert_eq!(
                y_ep, y_ref,
                "EP output must be bit-identical to single-rank (ep={world})"
            );
            let mode = if overlap { "overlap" } else { "sequential" };
            let speedup = if overlap && seq_ms > 0.0 {
                seq_ms / run.ms_per_iter
            } else {
                1.0
            };
            if !overlap {
                seq_ms = run.ms_per_iter;
            }
            table.row(&[
                format!("ep={world} {mode} (rounds={})", run.rounds),
                format!("{:.2}", run.ms_per_iter),
                format!("{:.0}", 100.0 * run.overlap_frac),
                run.launches.to_string(),
                format!("{:.2}", run.a2a_bytes as f64 / (1024.0 * 1024.0)),
                format!("{speedup:.2}x"),
            ]);
            json_rows.push(Json::obj([
                kv("ep", Json::from(world)),
                kv("mode", Json::from(mode)),
                kv("rounds", Json::from(run.rounds)),
                kv("ms_per_iter", Json::from(run.ms_per_iter)),
                kv("overlap_frac", Json::from(run.overlap_frac)),
                kv("launches", Json::from(run.launches)),
                kv("a2a_bytes", Json::from(run.a2a_bytes)),
                kv("a2a_ops", Json::from(run.a2a_ops)),
                kv("speedup_vs_sequential", Json::from(speedup)),
            ]));
            if overlap && world >= 2 {
                assert!(
                    run.overlap_frac > 0.0,
                    "overlapped EP must report comm/compute overlap"
                );
                if !smoke {
                    assert!(
                        run.ms_per_iter < seq_ms * 0.95,
                        "overlapped EP ({:.2} ms) must beat sequential \
                         ({seq_ms:.2} ms) at ep={world}",
                        run.ms_per_iter
                    );
                }
            }
        }
    }
    println!(
        "\n=== EP overlap: chunked all-to-all + pipelined expert compute \
         ({} experts, d={}, heavy/light {}/{}) ===",
        shape.n_experts, shape.d, shape.heavy, shape.light
    );
    table.print();

    let out = std::env::var("BENCH_JSON_OUT")
        .unwrap_or_else(|_| "../BENCH_moe_ep.json".to_string());
    let n_runs = json_rows.len();
    let doc = Json::obj([
        kv("bench", Json::from("table4_moe_ep")),
        kv("smoke", Json::from(smoke)),
        kv("iters", Json::from(iters)),
        kv(
            "shape",
            Json::obj([
                kv("d", Json::from(shape.d)),
                kv("f", Json::from(shape.f)),
                kv("n_experts", Json::from(shape.n_experts)),
                kv("heavy", Json::from(shape.heavy)),
                kv("light", Json::from(shape.light)),
            ]),
        ),
        kv("runs", Json::Arr(json_rows)),
    ]);
    std::fs::write(&out, doc.pretty())?;
    println!("wrote {out}");

    // schema check: re-read what we just wrote through the parser
    let parsed = json::parse(&std::fs::read_to_string(&out)?)?;
    assert_eq!(parsed.str_field("bench")?, "table4_moe_ep");
    assert_eq!(parsed.get("shape").and_then(|s| s.get("n_experts")).and_then(|v| v.as_usize()),
               Some(shape.n_experts));
    let runs = parsed.get("runs").and_then(|v| v.as_arr()).expect("runs array");
    assert_eq!(runs.len(), n_runs);
    for row in runs {
        row.str_field("mode")?;
        row.usize_field("ep")?;
        row.usize_field("rounds")?;
        row.usize_field("launches")?;
        row.usize_field("a2a_bytes")?;
        assert!(row.get("ms_per_iter").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("overlap_frac").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("speedup_vs_sequential").and_then(|v| v.as_f64()).is_some());
    }
    println!("schema check passed");
    Ok(())
}
