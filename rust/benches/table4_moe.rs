//! Paper Table 4 (top): MoE optimization ablation.
//! Baseline loop-over-experts vs GroupedGEMM (one batched launch) vs
//! MegaBlocks-style exact-fit tiles (dynamic launch count, no padding).

use linear_moe::bench_util::bench;
use linear_moe::coordinator::metrics::Table;
use linear_moe::coordinator::moe_ep::{ExpertWeights, MoeLayer, Strategy};
use linear_moe::rng::Rng;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("BENCH_ITERS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(8);
    let rt = Runtime::new("artifacts")?;
    let mut table = Table::new(&[
        "MoE execution", "time/iter ms", "launches", "padded slots",
    ]);
    let layer = MoeLayer::new(&rt, "bench")?;
    let mut rng = Rng::new(5);
    let f_dim = 256;
    let weights = ExpertWeights::random(&mut rng, layer.n_experts, layer.d, f_dim);
    let t = rt.manifest.artifact("moe_router_bench")?.args[1].shape[0];
    let router_w = Tensor::f32(&[layer.d, layer.n_experts],
        (0..layer.d * layer.n_experts).map(|_| rng.normal() * 0.02).collect());
    let x = Tensor::f32(&[t, layer.d],
        (0..t * layer.d).map(|_| rng.normal() * 0.5).collect());

    for (name, strat) in [("Baseline (loop)", Strategy::Loop),
                          ("Grouped GEMM", Strategy::Grouped),
                          ("MegaBlocks (tiles)", Strategy::MegaBlocks)] {
        let (_, counts, launches) =
            layer.forward_local(strat, &router_w, &weights, &x)?;
        let padded: usize = match strat {
            Strategy::Loop | Strategy::Grouped => counts.iter()
                .map(|&c| layer.cap.saturating_sub(c.min(layer.cap))).sum(),
            Strategy::MegaBlocks => counts.iter()
                .map(|&c| c.div_ceil(layer.tile) * layer.tile - c).sum(),
        };
        let r = bench(name, 2, iters, || {
            let _ = layer.forward_local(strat, &router_w, &weights, &x).unwrap();
        });
        table.row(&[name.to_string(), format!("{:.1}", r.mean_ms),
                    launches.to_string(), padded.to_string()]);
    }
    println!("\n=== Table 4 (top): MoE optimization ({t} tokens, {} experts) ===",
             layer.n_experts);
    table.print();
    Ok(())
}
