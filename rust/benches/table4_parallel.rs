//! Paper Table 4 (bottom): distributed-training ablation across EP/TP/PP.
//!
//! Two parts:
//!  1. *Memory per worker* for the paper's exact configs on the
//!     shape-faithful a0p3b preset (modeled; the paper's axis).
//!  2. *Measured time/iter* for the parallelism schemes this testbed
//!     executes end-to-end: DP (ZeRO-1 DDP over worker threads), PP
//!     (GPipe/1F1B over per-layer artifacts), EP (token dispatch).
//!     One physical core timeshares all workers, so wall-clock reflects
//!     total work + coordination overhead, not speedup (DESIGN.md).

use std::sync::Arc;

use linear_moe::coordinator::ddp::{run_ddp, DdpConfig};
use linear_moe::coordinator::metrics::Table;
use linear_moe::coordinator::pipeline::{simulate, Schedule};
use linear_moe::data;
use linear_moe::memcost::{self, ParallelCfg};
use linear_moe::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    // Part 1: modeled memory, paper configs (seq 2048, batch 4, A0.3B-like)
    let a0p3b = linear_moe::runtime::ModelConfig {
        vocab: 151936, d_model: 1024, n_heads: 8, d_head: 128, n_layers: 12,
        layout: "L".repeat(12), lsm: "gla".into(), chunk: 64,
        n_experts: 64, top_k: 8, d_ffn: 896, capacity_factor: 1.0,
    };
    let mut t1 = Table::new(&["EP", "TP", "PP", "mem/GPU GiB (model)"]);
    for (ep, tp, pp) in [(1, 1, 1), (8, 1, 1), (1, 8, 1), (1, 1, 8), (2, 2, 2)] {
        let p = ParallelCfg { dp: 1, sp: 1, pp, tp, ep, dist_opt: false };
        let gib = memcost::gib(memcost::train_bytes(&a0p3b, 4, 2048, &p, true));
        t1.row(&[ep.to_string(), tp.to_string(), pp.to_string(),
                 format!("{gib:.2}")]);
    }
    println!("\n=== Table 4 (bottom, part 1): modeled memory, A0.3B config ===");
    t1.print();

    // Part 2: measured time/iter on tiny artifacts.
    let steps = std::env::var("BENCH_ITERS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(3usize);
    let vocab = rt.manifest.variant("tiny_gla")?.config.vocab;
    drop(rt);
    let mut t2 = Table::new(&["scheme", "workers", "ms/iter", "comm MiB"]);
    for dp in [1usize, 2, 4, 8] {
        let bf: linear_moe::coordinator::ddp::BatchFn = Arc::new(move |idx, n| {
            let mut lm = data::ZipfLm::new(vocab, idx as u64);
            let b = data::batch_from_stream(&mut lm, 2, n);
            (b.tokens, b.targets)
        });
        let t0 = std::time::Instant::now();
        let rep = run_ddp(&DdpConfig {
            artifacts_dir: "artifacts".into(), tag: "tiny_gla".into(),
            batch: 2, seq: 128, dp, lr: 1e-3, steps, seed: 0,
        }, bf)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
        t2.row(&[format!("DP (ZeRO-1)"), dp.to_string(), format!("{ms:.0}"),
                 format!("{:.1}", (rep.traffic.0 + rep.traffic.1) as f64 / 1048576.0)]);
    }
    println!("\n=== Table 4 (bottom, part 2): measured DDP time/iter (tiny, incl. per-worker artifact compile in first lap) ===");
    t2.print();

    // Part 3: pipeline schedule simulation (bubble + peak memory)
    let mut t3 = Table::new(&["schedule", "stages", "microbatches",
                              "ticks (bubble proxy)", "peak live acts s0"]);
    for (st, m) in [(2usize, 8usize), (4, 8), (8, 8)] {
        for (name, k) in [("GPipe", Schedule::GPipe), ("1F1B", Schedule::OneF1B)] {
            let r = simulate(k, st, m)?;
            t3.row(&[name.to_string(), st.to_string(), m.to_string(),
                     r.ticks.to_string(), r.peak_live[0].to_string()]);
        }
    }
    println!("\n=== Table 4 (bottom, part 3): pipeline schedule ablation ===");
    t3.print();
    Ok(())
}
