//! Paper §2.2.1 / App. A.3: LASP sequence-parallelism scaling.
//! LASP-2 (one AllGather of d x d states) vs LASP-1 (ring chain) across SP
//! sizes, with measured communication volume -- the §2.2.2 claim that SP
//! comm for LSM layers is independent of sequence length, vs the
//! attention path whose all-gathered K/V grows with N.

use linear_moe::collectives::Comm;
use linear_moe::coordinator::metrics::Table;
use linear_moe::coordinator::sp::{AttnSpExecutor, GateKind, SpExecutor, SpMode};
use linear_moe::rng::Rng;
use linear_moe::runtime::Runtime;
use linear_moe::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let spec = rt.manifest.artifact("sp_state_vector")?;
    let ks = spec.args[0].shape.clone();
    let (b, h, c, dk) = (ks[0], ks[1], ks[2], ks[3]);
    drop(rt);
    let mut table = Table::new(&[
        "mode", "SP size", "ms/layer", "LSM comm KiB", "attn comm KiB",
    ]);
    for t_world in [2usize, 4, 8] {
        for (label, mode) in [("LASP-2 (AllGather)", SpMode::Lasp2AllGather),
                              ("LASP-1 (ring)", SpMode::Lasp1Ring)] {
            let (comm, handles) = Comm::new(t_world);
            let t0 = std::time::Instant::now();
            let joins: Vec<_> = handles.into_iter().map(|hdl| {
                std::thread::spawn(move || {
                    let rt = Runtime::new("artifacts").unwrap();
                    let ex = SpExecutor::new(&rt, GateKind::Vector).unwrap();
                    let attn = if matches!(mode, SpMode::Lasp2AllGather) {
                        AttnSpExecutor::new(&rt, hdl.world).ok()
                    } else { None };
                    let mut rng = Rng::new(hdl.rank as u64);
                    let mk = |rng: &mut Rng, shape: &[usize]| Tensor::f32(
                        shape, (0..shape.iter().product::<usize>())
                            .map(|_| rng.normal() * 0.5).collect());
                    let q = mk(&mut rng, &[b, h, c, dk]);
                    let k = mk(&mut rng, &[b, h, c, dk]);
                    let v = mk(&mut rng, &[b, h, c, dk]);
                    let g = Tensor::f32(&[b, h, c, dk],
                        (0..b * h * c * dk).map(|_| (-0.25 * rng.f32()).exp()).collect());
                    ex.run(&hdl, mode, &q, &k, &v, Some(&g)).unwrap();
                    if let Some(a) = attn {
                        a.run(&hdl, &q, &k, &v).unwrap();
                    }
                })
            }).collect();
            for j in joins { j.join().unwrap(); }
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let (ag, _, p2p, _) = comm.traffic();
            // attn K/V all-gather = 2 tensors per rank when LASP-2 row
            let attn_kib = if matches!(mode, SpMode::Lasp2AllGather) {
                (2 * b * h * c * dk * 4 * t_world) as f64 / 1024.0
            } else { 0.0 };
            let lsm_comm = if matches!(mode, SpMode::Lasp2AllGather) {
                ag as f64 / 1024.0 - attn_kib
            } else { p2p as f64 / 1024.0 };
            table.row(&[label.to_string(), t_world.to_string(),
                        format!("{ms:.0}"), format!("{lsm_comm:.0}"),
                        format!("{attn_kib:.0}")]);
        }
    }
    println!("\n=== LASP SP scaling (per-rank chunk {c} tokens, d_k {dk}) ===");
    table.print();
    println!("(LSM comm is per-layer-pass total across ranks; note it does \
              not grow with chunk length, while attn K/V comm does)");
    Ok(())
}
