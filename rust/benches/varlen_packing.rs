//! Paper §2.2.4: variable-length batching -- right-padding vs packing the
//! batch as one continuous sequence.  Reports wasted-token fraction and
//! effective training throughput (real tokens / s) through the eval_loss
//! artifact.

use linear_moe::coordinator::metrics::Table;
use linear_moe::data;
use linear_moe::rng::Rng;
use linear_moe::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("BENCH_ITERS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(5);
    let rt = Runtime::new("artifacts")?;
    let exe = rt.load("eval_loss_tiny_gla_b2n128")?;
    let params = rt.init_params("tiny_gla", 0)?;
    let mut lm = data::ZipfLm::new(2048, 1);
    let mut rng = Rng::new(2);
    let mut table = Table::new(&["strategy", "real-token eff", "real tok/s"]);
    for (name, packed) in [("right-padding", false), ("packed-continuous", true)] {
        let mut real = 0usize;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            let lens = data::sample_doc_lengths(&mut rng, 48, 40, 128);
            let docs: Vec<Vec<i32>> = lens.iter().map(|&l| lm.document(l)).collect();
            let b = if packed {
                data::batch_packed(&docs, 2, 128).0
            } else {
                data::batch_padded(&docs, 2, 128, 0)
            };
            real += b.real_tokens;
            let out = exe.run_bundled(&[&params], &[&b.tokens, &b.targets])?;
            std::hint::black_box(out[1].item_f32()?);
        }
        let dt = t0.elapsed().as_secs_f64();
        table.row(&[name.to_string(),
                    format!("{:.2}", real as f64 / (iters * 2 * 128) as f64),
                    format!("{:.0}", real as f64 / dt)]);
    }
    println!("\n=== §2.2.4: variable-length handling ===");
    table.print();
    Ok(())
}
