//! Data pipeline: synthetic corpus generation, batching, and the paper's
//! §2.2.4 variable-length handling (right-padding vs packing the whole
//! batch as one continuous sequence).
//!
//! The corpus substitutes for SlimPajama (see DESIGN.md): a deterministic
//! mixture of (a) a Zipfian unigram/bigram language with enough structure
//! for loss curves to move, and (b) recall probes (phonebook lookups /
//! needle-in-a-haystack) exercising exactly the capability the paper's
//! Tables 5/6 compare pure vs hybrid models on.

use crate::rng::Rng;
use crate::tensor::Tensor;

pub const PAD_TARGET: i32 = -1;

/// A Zipf-flavoured Markov language: each token deterministically maps to
/// a successor with occasional Zipf resampling.  Learnable structure whose
/// CE sits well below uniform log(V).
pub struct ZipfLm {
    vocab: usize,
    succ: Vec<i32>,
    rng: Rng,
    /// probability of breaking the chain with a Zipf draw
    pub noise: f32,
}

impl ZipfLm {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // random successor permutation-ish map
        let succ = (0..vocab)
            .map(|_| rng.below(vocab) as i32)
            .collect();
        ZipfLm { vocab, succ, rng, noise: 0.15 }
    }

    pub fn next_token(&mut self, prev: i32) -> i32 {
        if self.rng.f32() < self.noise {
            self.rng.zipf(self.vocab, 1.2) as i32
        } else {
            self.succ[prev as usize]
        }
    }

    /// One document of `len` tokens.
    pub fn document(&mut self, len: usize) -> Vec<i32> {
        let mut doc = Vec::with_capacity(len);
        let mut t = self.rng.zipf(self.vocab, 1.2) as i32;
        for _ in 0..len {
            doc.push(t);
            t = self.next_token(t);
        }
        doc
    }
}

/// A (tokens, targets) training batch of shape (B, N).
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Tensor,
    pub targets: Tensor,
    pub real_tokens: usize,
    pub total_tokens: usize,
}

impl Batch {
    /// Fraction of positions carrying a real next-token target.
    pub fn efficiency(&self) -> f64 {
        self.real_tokens as f64 / self.total_tokens as f64
    }
}

/// Build a batch from fixed-length documents (pretraining path).
pub fn batch_from_stream(lm: &mut ZipfLm, b: usize, n: usize) -> Batch {
    let mut toks = Vec::with_capacity(b * n);
    let mut tgts = Vec::with_capacity(b * n);
    for _ in 0..b {
        let doc = lm.document(n + 1);
        toks.extend_from_slice(&doc[..n]);
        tgts.extend_from_slice(&doc[1..n + 1]);
    }
    Batch {
        tokens: Tensor::i32(&[b, n], toks),
        targets: Tensor::i32(&[b, n], tgts),
        real_tokens: b * n,
        total_tokens: b * n,
    }
}

/// Variable-length documents, **right-padded** to the batch max (the
/// baseline strategy in §2.2.4; padded positions are masked in the loss
/// and wasted in compute).  Batch shape is (b, n): docs longer than n are
/// truncated.
pub fn batch_padded(docs: &[Vec<i32>], b: usize, n: usize, pad_tok: i32) -> Batch {
    assert!(docs.len() >= b);
    let mut toks = vec![pad_tok; b * n];
    let mut tgts = vec![PAD_TARGET; b * n];
    let mut real = 0usize;
    for (r, doc) in docs.iter().take(b).enumerate() {
        let len = doc.len().min(n + 1);
        let usable = len.saturating_sub(1);
        for i in 0..usable {
            toks[r * n + i] = doc[i];
            tgts[r * n + i] = doc[i + 1];
            real += 1;
        }
    }
    Batch {
        tokens: Tensor::i32(&[b, n], toks),
        targets: Tensor::i32(&[b, n], tgts),
        real_tokens: real,
        total_tokens: b * n,
    }
}

/// Variable-length documents **packed** as one continuous sequence
/// (the Linear-MoE strategy in §2.2.4: no padding; documents are
/// concatenated and only the cross-document boundary target is masked).
/// Consumes as many docs as fit; returns (batch, docs consumed).
pub fn batch_packed(docs: &[Vec<i32>], b: usize, n: usize) -> (Batch, usize) {
    let mut toks = Vec::with_capacity(b * n);
    let mut tgts = Vec::with_capacity(b * n);
    let mut used = 0usize;
    let mut real = 0usize;
    'outer: for doc in docs {
        for (i, &t) in doc.iter().enumerate() {
            if toks.len() == b * n {
                break 'outer;
            }
            toks.push(t);
            if i + 1 < doc.len() {
                tgts.push(doc[i + 1]);
                real += 1;
            } else {
                tgts.push(PAD_TARGET); // document boundary
            }
        }
        used += 1;
    }
    // tail fill (only when we ran out of documents)
    while toks.len() < b * n {
        toks.push(0);
        tgts.push(PAD_TARGET);
    }
    real = real.min(b * n);
    (
        Batch {
            tokens: Tensor::i32(&[b, n], toks),
            targets: Tensor::i32(&[b, n], tgts),
            real_tokens: real,
            total_tokens: b * n,
        },
        used,
    )
}

/// Sample variable document lengths (rough lognormal, clamped).
pub fn sample_doc_lengths(rng: &mut Rng, count: usize, mean: usize, max: usize) -> Vec<usize> {
    (0..count)
        .map(|_| {
            let z = rng.normal() as f64;
            let len = (mean as f64 * (0.6 * z).exp()) as usize;
            len.clamp(8, max)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Recall probes (Tables 5/6 substitution).
// ---------------------------------------------------------------------------

/// A phonebook-lookup episode: `pairs` (key, value) entries followed by a
/// query key; the model must emit the matching value.
/// Encoding: [SEP k v] * pairs [QUERY k] -> answer v.
/// Token space: keys/values are drawn from disjoint vocab ranges so the
/// task is unambiguous.
pub struct RecallEpisode {
    pub prompt: Vec<i32>,
    pub answer: i32,
}

pub fn phonebook_episode(rng: &mut Rng, vocab: usize, pairs: usize) -> RecallEpisode {
    let sep = 0i32;
    let query = 1i32;
    let kspace = (vocab - 2) / 2;
    let mut keys: Vec<usize> = (0..kspace).collect();
    rng.shuffle(&mut keys);
    let mut prompt = Vec::with_capacity(pairs * 3 + 2);
    let mut kv = Vec::with_capacity(pairs);
    for &k in keys.iter().take(pairs) {
        let v = 2 + kspace + rng.below(kspace);
        prompt.push(sep);
        prompt.push(2 + k as i32);
        prompt.push(v as i32);
        kv.push((2 + k as i32, v as i32));
    }
    let (qk, qv) = kv[rng.below(kv.len())];
    prompt.push(query);
    prompt.push(qk);
    RecallEpisode { prompt, answer: qv }
}

/// Needle-in-a-haystack: a (needle-key, needle-value) pair buried at a
/// random depth inside `haystack_len` filler tokens, queried at the end.
pub fn niah_episode(
    rng: &mut Rng,
    vocab: usize,
    haystack_len: usize,
) -> RecallEpisode {
    let sep = 0i32;
    let query = 1i32;
    let key = 2 + rng.below((vocab - 2) / 2) as i32;
    let val = (2 + (vocab - 2) / 2 + rng.below((vocab - 2) / 2)) as i32;
    let mut prompt: Vec<i32> = (0..haystack_len)
        .map(|_| (2 + rng.zipf(vocab - 2, 1.2)) as i32)
        .collect();
    let depth = rng.below(haystack_len.saturating_sub(3).max(1));
    prompt[depth] = sep;
    prompt[depth + 1] = key;
    prompt[depth + 2] = val;
    prompt.push(query);
    prompt.push(key);
    RecallEpisode { prompt, answer: val }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::check;

    #[test]
    fn stream_batch_shapes() {
        let mut lm = ZipfLm::new(512, 1);
        let b = batch_from_stream(&mut lm, 4, 64);
        assert_eq!(b.tokens.shape, vec![4, 64]);
        assert_eq!(b.efficiency(), 1.0);
        // targets are the shifted tokens
        let t = b.tokens.as_i32().unwrap();
        let g = b.targets.as_i32().unwrap();
        assert_eq!(t[1], g[0]);
    }

    #[test]
    fn packing_beats_padding_efficiency() {
        // The §2.2.4 claim: under variable lengths, packing wastes (almost)
        // nothing while padding wastes proportionally to length variance.
        let mut lm = ZipfLm::new(512, 2);
        let mut rng = Rng::new(3);
        let lens = sample_doc_lengths(&mut rng, 64, 48, 256);
        let docs: Vec<Vec<i32>> = lens.iter().map(|&l| lm.document(l)).collect();
        let padded = batch_padded(&docs, 8, 256, 0);
        let (packed, used) = batch_packed(&docs, 8, 256);
        assert!(used > 8, "packing should consume more docs");
        assert!(packed.efficiency() > 0.9, "packed eff {}", packed.efficiency());
        assert!(padded.efficiency() < 0.6, "padded eff {}", padded.efficiency());
    }

    #[test]
    fn packed_batch_is_boundary_masked() {
        let docs = vec![vec![5, 6, 7], vec![8, 9]];
        let (b, used) = batch_packed(&docs, 1, 8);
        assert_eq!(used, 2);
        let t = b.tokens.as_i32().unwrap();
        let g = b.targets.as_i32().unwrap();
        assert_eq!(&t[..5], &[5, 6, 7, 8, 9]);
        assert_eq!(g[0], 6);
        assert_eq!(g[2], PAD_TARGET); // boundary after doc 1
        assert_eq!(g[3], 9);
        assert_eq!(g[4], PAD_TARGET);
    }

    #[test]
    fn recall_episode_properties() {
        check("phonebook_wellformed", 64, |rng| {
            let ep = phonebook_episode(rng, 256, 8);
            assert_eq!(ep.prompt.len(), 8 * 3 + 2);
            // answer is a value-range token
            assert!(ep.answer >= 2 + 127);
            // query key appears in the prompt body
            let qk = *ep.prompt.last().unwrap();
            assert!(ep.prompt[..ep.prompt.len() - 2].contains(&qk));
        });
        check("niah_wellformed", 64, |rng| {
            let ep = niah_episode(rng, 256, 64);
            assert_eq!(ep.prompt.len(), 64 + 2);
            assert!(ep.answer >= 2);
        });
    }
}
