//! Unified tracing & metrics: spans, instants, counters, and exporters.
//!
//! Every event carries **dual timestamps**:
//!
//! * a deterministic `tick` in whatever logical clock the emitting
//!   subsystem runs on (engine tick, training step, EP round), and
//! * optional wall-clock fields (`wall_us` start, `wall_dur_us`
//!   duration, microseconds since the tracer's epoch) for real latency.
//!
//! The tick-domain half of every export is bitwise-reproducible across
//! reruns with the same seed; the wall fields are the documented
//! nondeterministic exception and can be stripped (`include_wall =
//! false`) to obtain a byte-stable artifact suitable for golden tests.
//!
//! Determinism model: events land in a single `Mutex<Vec<_>>`, so the
//! *global* interleaving across threads is arbitrary, but each thread's
//! own pushes keep program order. Exports stable-sort by [`Track`]
//! (process name + lane), and every track in this codebase is written
//! by exactly one thread at a time, so per-track event order — and
//! therefore the sorted export — is deterministic.
//!
//! Two exporters share the [`crate::json`] writer:
//!
//! * **JSONL** — one compact JSON object per event, one per line.
//! * **Chrome/Perfetto `trace_event` JSON** — load via
//!   <https://ui.perfetto.dev> or `chrome://tracing`. Ticks are scaled
//!   to 1 tick = 1000 "µs" so spans are visible at any zoom.
//!
//! A [`MetricsRegistry`] of named counters/gauges/histograms rides on
//! the same tracer and unifies the scattered one-off stat structs
//! (`CommTraffic`, `HealthBoard`, `ServeOutcomes`, ...) — see
//! `coordinator::obs` for the adapters.

use crate::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Where an event is drawn: a named process row and a lane (thread row)
/// within it. Examples: `("engine", 0)`, `("comm", rank)`, `("req", id)`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Track {
    pub process: String,
    pub lane: u64,
}

impl Track {
    pub fn new(process: &str, lane: u64) -> Self {
        Track { process: process.to_string(), lane }
    }
}

/// Event payload kind, mirroring the Chrome trace-event phases we emit:
/// complete spans (`X`), instants (`i`), and counter samples (`C`).
#[derive(Debug, Clone, PartialEq)]
pub enum Kind {
    /// An interval starting at `tick` lasting `dur_ticks` logical ticks
    /// (0 means "within one tick"; wall duration may still be nonzero).
    Span { dur_ticks: u64 },
    /// A point-in-time marker.
    Instant,
    /// A sampled counter value (rendered as a counter track).
    Counter { value: f64 },
}

/// One trace event. `args` hold deterministic key/values only; wall
/// times live in the dedicated optional fields so they can be stripped.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub track: Track,
    /// Category: "comm", "ep", "serve", "fault", "recovery", ...
    pub cat: &'static str,
    pub name: String,
    /// Deterministic logical time (engine tick / training step / round).
    pub tick: u64,
    pub kind: Kind,
    pub args: Vec<(String, Json)>,
    /// Wall-clock start, µs since tracer epoch. Nondeterministic.
    pub wall_us: Option<f64>,
    /// Wall-clock duration in µs. Nondeterministic.
    pub wall_dur_us: Option<f64>,
}

impl Event {
    pub fn to_json(&self, include_wall: bool) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("process".to_string(), Json::from(self.track.process.as_str())),
            ("lane".to_string(), Json::from(self.track.lane)),
            ("cat".to_string(), Json::from(self.cat)),
            ("name".to_string(), Json::from(self.name.as_str())),
            ("tick".to_string(), Json::from(self.tick)),
        ];
        match &self.kind {
            Kind::Span { dur_ticks } => {
                pairs.push(("kind".to_string(), Json::from("span")));
                pairs.push(("dur_ticks".to_string(), Json::from(*dur_ticks)));
            }
            Kind::Instant => pairs.push(("kind".to_string(), Json::from("instant"))),
            Kind::Counter { value } => {
                pairs.push(("kind".to_string(), Json::from("counter")));
                pairs.push(("value".to_string(), Json::from(*value)));
            }
        }
        if !self.args.is_empty() {
            pairs.push(("args".to_string(), Json::obj(self.args.iter().cloned())));
        }
        if include_wall {
            if let Some(w) = self.wall_us {
                pairs.push(("wall_us".to_string(), Json::from(w)));
            }
            if let Some(d) = self.wall_dur_us {
                pairs.push(("wall_dur_us".to_string(), Json::from(d)));
            }
        }
        Json::obj(pairs)
    }
}

/// A histogram that keeps raw samples (traces here are small: thousands
/// of events, not millions) and rejects non-finite observations.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Histogram {
    samples: Vec<f64>,
    rejected: u64,
}

impl Histogram {
    /// Record one sample. Non-finite values are counted in
    /// [`Histogram::rejected`] and return `false` instead of poisoning
    /// every percentile downstream.
    pub fn observe(&mut self, v: f64) -> bool {
        if v.is_finite() {
            self.samples.push(v);
            true
        } else {
            self.rejected += 1;
            false
        }
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Nearest-rank percentile (same convention as `metrics::Summary`):
    /// index `floor(n * q)` clamped to the last sample. `None` when
    /// empty; with one sample every percentile is that sample.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let idx = ((n as f64) * q.clamp(0.0, 1.0)) as usize;
        Some(sorted[idx.min(n - 1)])
    }

    pub fn min(&self) -> Option<f64> {
        self.samples.iter().copied().min_by(f64::total_cmp)
    }

    pub fn max(&self) -> Option<f64> {
        self.samples.iter().copied().max_by(f64::total_cmp)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::from).unwrap_or(Json::Null);
        Json::obj([
            ("n".to_string(), Json::from(self.n())),
            ("rejected".to_string(), Json::from(self.rejected)),
            ("min".to_string(), opt(self.min())),
            ("mean".to_string(), opt(self.mean())),
            ("p50".to_string(), opt(self.percentile(0.50))),
            ("p95".to_string(), opt(self.percentile(0.95))),
            ("p99".to_string(), opt(self.percentile(0.99))),
            ("max".to_string(), opt(self.max())),
        ])
    }
}

/// Named counters, gauges, and histograms. All maps are `BTreeMap` so
/// [`MetricsRegistry::to_json`] is deterministic.
#[derive(Debug, Default, Clone)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record a histogram sample; returns `false` (and counts the
    /// rejection) for non-finite values.
    pub fn observe(&mut self, name: &str, v: f64) -> bool {
        self.histograms.entry(name.to_string()).or_default().observe(v)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::from(*v))),
        );
        let gauges = Json::obj(
            self.gauges.iter().map(|(k, v)| (k.clone(), Json::from(*v))),
        );
        let histograms = Json::obj(
            self.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())),
        );
        Json::obj([
            ("counters".to_string(), counters),
            ("gauges".to_string(), gauges),
            ("histograms".to_string(), histograms),
        ])
    }
}

/// The shared trace buffer. Cheap to emit into (one short mutex hold
/// per event); reading/exporting clones the buffer.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    events: Mutex<Vec<Event>>,
    metrics: Mutex<MetricsRegistry>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer {
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
            metrics: Mutex::new(MetricsRegistry::default()),
        }
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Microseconds of wall clock since this tracer was created.
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    pub fn emit(&self, ev: Event) {
        self.events.lock().expect("trace buffer poisoned").push(ev);
    }

    /// Raw events in arrival order (nondeterministic across threads).
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().expect("trace buffer poisoned").clone()
    }

    /// Events stable-sorted by track. Each track is written by one
    /// thread at a time, so this order is deterministic.
    pub fn sorted_events(&self) -> Vec<Event> {
        let mut evs = self.events();
        evs.sort_by(|a, b| a.track.cmp(&b.track));
        evs
    }

    pub fn with_metrics<R>(&self, f: impl FnOnce(&mut MetricsRegistry) -> R) -> R {
        f(&mut self.metrics.lock().expect("metrics registry poisoned"))
    }

    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        self.metrics.lock().expect("metrics registry poisoned").clone()
    }

    /// One compact JSON object per line. With `include_wall = false`
    /// the output is bitwise-deterministic for a fixed seed.
    pub fn to_jsonl(&self, include_wall: bool) -> String {
        let mut out = String::new();
        for ev in self.sorted_events() {
            ev.to_json(include_wall).write(&mut out);
            out.push('\n');
        }
        out
    }

    /// Chrome `trace_event` JSON (the "JSON Array Format" object with
    /// `traceEvents`). Logical ticks are scaled ×1000 so that events
    /// sharing a tick can be separated by a per-track sub-sequence
    /// offset while preserving order.
    pub fn to_perfetto(&self, include_wall: bool) -> String {
        let evs = self.sorted_events();
        // Stable process-name -> pid mapping (sorted, 1-based).
        let mut pids: BTreeMap<&str, u64> = BTreeMap::new();
        for ev in &evs {
            let next = pids.len() as u64 + 1;
            pids.entry(ev.track.process.as_str()).or_insert(next);
        }
        let mut trace_events: Vec<Json> = Vec::new();
        let mut threads: BTreeMap<(u64, u64), String> = BTreeMap::new();
        for ev in &evs {
            let pid = pids[ev.track.process.as_str()];
            threads
                .entry((pid, ev.track.lane))
                .or_insert_with(|| format!("{} {}", ev.track.process, ev.track.lane));
        }
        for (name, pid) in &pids {
            trace_events.push(Json::obj([
                ("ph".to_string(), Json::from("M")),
                ("pid".to_string(), Json::from(*pid)),
                ("name".to_string(), Json::from("process_name")),
                (
                    "args".to_string(),
                    Json::obj([("name".to_string(), Json::from(*name))]),
                ),
            ]));
        }
        for ((pid, tid), label) in &threads {
            trace_events.push(Json::obj([
                ("ph".to_string(), Json::from("M")),
                ("pid".to_string(), Json::from(*pid)),
                ("tid".to_string(), Json::from(*tid)),
                ("name".to_string(), Json::from("thread_name")),
                (
                    "args".to_string(),
                    Json::obj([("name".to_string(), Json::from(label.as_str()))]),
                ),
            ]));
        }
        // Per-(track, tick) sub-sequence keeps same-tick events ordered.
        let mut seq: BTreeMap<(u64, u64), (u64, u64)> = BTreeMap::new();
        for ev in &evs {
            let pid = pids[ev.track.process.as_str()];
            let slot = seq.entry((pid, ev.track.lane)).or_insert((u64::MAX, 0));
            if slot.0 == ev.tick {
                slot.1 += 1;
            } else {
                *slot = (ev.tick, 0);
            }
            let ts = ev.tick * 1000 + slot.1;
            let mut pairs: Vec<(String, Json)> = vec![
                ("pid".to_string(), Json::from(pid)),
                ("tid".to_string(), Json::from(ev.track.lane)),
                ("cat".to_string(), Json::from(ev.cat)),
                ("name".to_string(), Json::from(ev.name.as_str())),
                ("ts".to_string(), Json::from(ts)),
            ];
            let mut args: Vec<(String, Json)> = ev.args.clone();
            args.push(("tick".to_string(), Json::from(ev.tick)));
            if include_wall {
                if let Some(w) = ev.wall_us {
                    args.push(("wall_us".to_string(), Json::from(w)));
                }
                if let Some(d) = ev.wall_dur_us {
                    args.push(("wall_dur_us".to_string(), Json::from(d)));
                }
            }
            match &ev.kind {
                Kind::Span { dur_ticks } => {
                    pairs.push(("ph".to_string(), Json::from("X")));
                    pairs.push((
                        "dur".to_string(),
                        Json::from((dur_ticks * 1000).max(1)),
                    ));
                }
                Kind::Instant => {
                    pairs.push(("ph".to_string(), Json::from("i")));
                    pairs.push(("s".to_string(), Json::from("t")));
                }
                Kind::Counter { value } => {
                    pairs.push(("ph".to_string(), Json::from("C")));
                    args.push(("value".to_string(), Json::from(*value)));
                }
            }
            pairs.push(("args".to_string(), Json::obj(args)));
            trace_events.push(Json::obj(pairs));
        }
        Json::obj([
            ("displayTimeUnit".to_string(), Json::from("ms")),
            ("traceEvents".to_string(), Json::Arr(trace_events)),
        ])
        .to_string()
    }

    /// Write both exports next to `path` and return
    /// `(jsonl_path, perfetto_path)`. `*.jsonl` → event log at `path`,
    /// Perfetto beside it as `*.perfetto.json`; `*.json` → Perfetto at
    /// `path`, event log beside it as `*.jsonl`; any other path gets
    /// both extensions appended.
    pub fn write_outputs(&self, path: &str) -> Result<(String, String)> {
        let (jsonl_path, perfetto_path) = if let Some(stem) = path.strip_suffix(".jsonl") {
            (path.to_string(), format!("{stem}.perfetto.json"))
        } else if let Some(stem) = path.strip_suffix(".json") {
            (format!("{stem}.jsonl"), path.to_string())
        } else {
            (format!("{path}.jsonl"), format!("{path}.perfetto.json"))
        };
        std::fs::write(&jsonl_path, self.to_jsonl(true))
            .with_context(|| format!("writing trace event log {jsonl_path}"))?;
        std::fs::write(&perfetto_path, self.to_perfetto(true))
            .with_context(|| format!("writing perfetto trace {perfetto_path}"))?;
        Ok((jsonl_path, perfetto_path))
    }

    /// Human-readable digest: event counts per category and the
    /// metrics registry, deterministic line order.
    pub fn summary(&self) -> String {
        let evs = self.sorted_events();
        let mut by_cat: BTreeMap<&str, usize> = BTreeMap::new();
        let mut tracks: BTreeMap<&Track, usize> = BTreeMap::new();
        for ev in &evs {
            *by_cat.entry(ev.cat).or_insert(0) += 1;
            *tracks.entry(&ev.track).or_insert(0) += 1;
        }
        let mut out = format!(
            "trace: {} events on {} tracks\n",
            evs.len(),
            tracks.len()
        );
        for (cat, n) in &by_cat {
            out.push_str(&format!("  cat {cat:<10} {n} events\n"));
        }
        let metrics = self.metrics_snapshot();
        if !metrics.is_empty() {
            out.push_str("  metrics: ");
            metrics.to_json().write(&mut out);
            out.push('\n');
        }
        out
    }
}

/// Cloneable, optional handle threaded through configs. `Default` /
/// [`TraceHandle::none`] is a no-op sink: every emit is one branch.
#[derive(Debug, Clone, Default)]
pub struct TraceHandle(Option<Arc<Tracer>>);

impl TraceHandle {
    pub fn none() -> Self {
        TraceHandle(None)
    }

    pub fn active() -> Self {
        TraceHandle(Some(Arc::new(Tracer::new())))
    }

    pub fn on(&self) -> bool {
        self.0.is_some()
    }

    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.0.as_ref()
    }

    pub fn emit(&self, ev: Event) {
        if let Some(t) = &self.0 {
            t.emit(ev);
        }
    }

    /// Tick-domain span with no wall timing.
    pub fn span(
        &self,
        track: Track,
        cat: &'static str,
        name: &str,
        tick: u64,
        dur_ticks: u64,
        args: Vec<(String, Json)>,
    ) {
        if let Some(t) = &self.0 {
            t.emit(Event {
                track,
                cat,
                name: name.to_string(),
                tick,
                kind: Kind::Span { dur_ticks },
                args,
                wall_us: None,
                wall_dur_us: None,
            });
        }
    }

    /// Span with a measured wall duration that just ended (wall start
    /// is back-dated by `wall_dur` from now).
    pub fn span_timed(
        &self,
        track: Track,
        cat: &'static str,
        name: &str,
        tick: u64,
        dur_ticks: u64,
        wall_dur: Duration,
        args: Vec<(String, Json)>,
    ) {
        if let Some(t) = &self.0 {
            let dur_us = wall_dur.as_secs_f64() * 1e6;
            t.emit(Event {
                track,
                cat,
                name: name.to_string(),
                tick,
                kind: Kind::Span { dur_ticks },
                args,
                wall_us: Some((t.now_us() - dur_us).max(0.0)),
                wall_dur_us: Some(dur_us),
            });
        }
    }

    pub fn instant(
        &self,
        track: Track,
        cat: &'static str,
        name: &str,
        tick: u64,
        args: Vec<(String, Json)>,
    ) {
        if let Some(t) = &self.0 {
            let now = t.now_us();
            t.emit(Event {
                track,
                cat,
                name: name.to_string(),
                tick,
                kind: Kind::Instant,
                args,
                wall_us: Some(now),
                wall_dur_us: None,
            });
        }
    }

    pub fn counter(
        &self,
        track: Track,
        cat: &'static str,
        name: &str,
        tick: u64,
        value: f64,
    ) {
        if let Some(t) = &self.0 {
            t.emit(Event {
                track,
                cat,
                name: name.to_string(),
                tick,
                kind: Kind::Counter { value },
                args: Vec::new(),
                wall_us: None,
                wall_dur_us: None,
            });
        }
    }

    pub fn inc(&self, name: &str, by: u64) {
        if let Some(t) = &self.0 {
            t.with_metrics(|m| m.inc(name, by));
        }
    }

    pub fn gauge(&self, name: &str, v: f64) {
        if let Some(t) = &self.0 {
            t.with_metrics(|m| m.gauge(name, v));
        }
    }

    pub fn observe(&self, name: &str, v: f64) -> bool {
        match &self.0 {
            Some(t) => t.with_metrics(|m| m.observe(name, v)),
            None => false,
        }
    }
}

/// Shorthand for building deterministic `args` lists:
/// `targs![("rank", rank), ("bytes", n)]` — values go through
/// `Json::from`.
#[macro_export]
macro_rules! targs {
    ($(($k:expr, $v:expr)),* $(,)?) => {
        vec![$(($k.to_string(), $crate::json::Json::from($v))),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn ev(process: &str, lane: u64, name: &str, tick: u64) -> Event {
        Event {
            track: Track::new(process, lane),
            cat: "test",
            name: name.to_string(),
            tick,
            kind: Kind::Instant,
            args: Vec::new(),
            wall_us: Some(123.456),
            wall_dur_us: None,
        }
    }

    #[test]
    fn jsonl_strips_wall_fields_and_sorts_by_track() {
        let t = Tracer::new();
        t.emit(ev("b", 0, "second", 5));
        t.emit(ev("a", 1, "first", 9));
        let out = t.to_jsonl(false);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"first\""), "track sort: {out}");
        assert!(!out.contains("wall_us"), "wall stripped: {out}");
        let with_wall = t.to_jsonl(true);
        assert!(with_wall.contains("wall_us"));
        for line in with_wall.lines() {
            json::parse(line).expect("each jsonl line parses");
        }
    }

    #[test]
    fn per_track_order_is_preserved_under_stable_sort() {
        let t = Tracer::new();
        // Interleave two tracks; per-track order must survive sorting.
        t.emit(ev("x", 0, "x0", 1));
        t.emit(ev("y", 0, "y0", 7));
        t.emit(ev("x", 0, "x1", 1));
        t.emit(ev("y", 0, "y1", 2)); // ticks non-monotonic: order still kept
        let names: Vec<String> = t.sorted_events().into_iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["x0", "x1", "y0", "y1"]);
    }

    #[test]
    fn perfetto_parses_and_contains_metadata_and_spans() {
        let t = Tracer::new();
        t.emit(Event {
            track: Track::new("engine", 0),
            cat: "serve",
            name: "engine.step".to_string(),
            tick: 3,
            kind: Kind::Span { dur_ticks: 1 },
            args: vec![("active".to_string(), Json::from(2u64))],
            wall_us: None,
            wall_dur_us: None,
        });
        t.emit(ev("engine", 0, "mark", 3));
        let doc = json::parse(&t.to_perfetto(true)).expect("perfetto parses");
        let evs = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        // 1 process_name + 1 thread_name + 2 events
        assert_eq!(evs.len(), 4);
        let phases: Vec<&str> = evs.iter().filter_map(|e| {
            e.get("ph").and_then(Json::as_str)
        }).collect();
        assert_eq!(phases, vec!["M", "M", "X", "i"]);
        let span = &evs[2];
        assert_eq!(span.get("ts").and_then(Json::as_f64), Some(3000.0));
        assert_eq!(span.get("dur").and_then(Json::as_f64), Some(1000.0));
        // Same tick, later in track order -> sub-sequence offset.
        assert_eq!(evs[3].get("ts").and_then(Json::as_f64), Some(3001.0));
    }

    #[test]
    fn histogram_edge_cases() {
        let mut h = Histogram::default();
        assert_eq!(h.percentile(0.5), None, "empty histogram");
        assert_eq!(h.min(), None);
        assert!(h.observe(7.0));
        assert_eq!(h.percentile(0.0), Some(7.0), "n=1: every percentile");
        assert_eq!(h.percentile(0.99), Some(7.0));
        assert!(!h.observe(f64::NAN), "NaN rejected");
        assert!(!h.observe(f64::INFINITY), "inf rejected");
        assert_eq!(h.rejected(), 2);
        assert_eq!(h.n(), 1, "rejected samples not stored");
        // Even n: nearest-rank convention, idx = floor(n*q).
        let mut h = Histogram::default();
        for v in [4.0, 1.0, 3.0, 2.0] {
            h.observe(v);
        }
        assert_eq!(h.percentile(0.50), Some(3.0));
        assert_eq!(h.percentile(0.99), Some(4.0));
        assert_eq!(h.min(), Some(1.0));
        assert_eq!(h.max(), Some(4.0));
        assert_eq!(h.mean(), Some(2.5));
    }

    #[test]
    fn registry_roundtrip_and_no_op_handle() {
        let h = TraceHandle::active();
        h.inc("comm.bytes", 10);
        h.inc("comm.bytes", 5);
        h.gauge("occupancy", 0.75);
        assert!(h.observe("lat", 3.0));
        assert!(!h.observe("lat", f64::NAN));
        let t = h.tracer().unwrap();
        let m = t.metrics_snapshot();
        assert_eq!(m.counter("comm.bytes"), 15);
        assert_eq!(m.gauge_value("occupancy"), Some(0.75));
        assert_eq!(m.histogram("lat").unwrap().n(), 1);
        json::parse(&m.to_json().to_string()).expect("metrics json parses");

        let off = TraceHandle::none();
        assert!(!off.on());
        off.inc("x", 1);
        off.span(Track::new("p", 0), "c", "n", 0, 0, Vec::new());
        assert!(!off.observe("x", 1.0), "no-op handle records nothing");
    }

    #[test]
    fn write_outputs_extension_rules() {
        let t = Tracer::new();
        t.emit(ev("p", 0, "n", 0));
        let dir = std::env::temp_dir().join("linear_moe_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("t.jsonl");
        let (j, p) = t.write_outputs(base.to_str().unwrap()).unwrap();
        assert!(j.ends_with("t.jsonl"));
        assert!(p.ends_with("t.perfetto.json"));
        let jl = std::fs::read_to_string(&j).unwrap();
        json::parse(jl.lines().next().unwrap()).unwrap();
        json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        let base2 = dir.join("t2.json");
        let (j2, p2) = t.write_outputs(base2.to_str().unwrap()).unwrap();
        assert!(j2.ends_with("t2.jsonl"));
        assert!(p2.ends_with("t2.json"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
