//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the contract between the build-time Python layers and
//! the Rust runtime: artifact names, flattened argument/result specs (in
//! HLO parameter order), model variant configs and parameter trees.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct LeafSpec {
    /// Pytree path, e.g. "[0]['layers'][0]['mixer']['wq']".
    pub path: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl LeafSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub args: Vec<LeafSpec>,
    pub results: Vec<LeafSpec>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub layout: String,
    pub lsm: String,
    pub chunk: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ffn: usize,
    pub capacity_factor: f64,
}

#[derive(Clone, Debug)]
pub struct Variant {
    pub tag: String,
    pub preset: String,
    pub instance: String,
    pub arch: String,
    pub config: ModelConfig,
    pub params_total: usize,
    pub params_activated: usize,
    pub param_specs: Vec<LeafSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn leaf_specs(v: &Json) -> Result<Vec<LeafSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of leaf specs"))?
        .iter()
        .map(|e| {
            Ok(LeafSpec {
                path: e.str_field("path")?,
                shape: e
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow!("missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                dtype: e.str_field("dtype")?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;

        let mut variants = BTreeMap::new();
        for (tag, v) in root
            .get("variants")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing variants"))?
        {
            let c = v.get("config").ok_or_else(|| anyhow!("variant missing config"))?;
            variants.insert(
                tag.clone(),
                Variant {
                    tag: tag.clone(),
                    preset: v.str_field("preset")?,
                    instance: v.str_field("instance")?,
                    arch: v.str_field("arch")?,
                    config: ModelConfig {
                        vocab: c.usize_field("vocab")?,
                        d_model: c.usize_field("d_model")?,
                        n_heads: c.usize_field("n_heads")?,
                        d_head: c.usize_field("d_head")?,
                        n_layers: c.usize_field("n_layers")?,
                        layout: c.str_field("layout")?,
                        lsm: c.str_field("lsm")?,
                        chunk: c.usize_field("chunk")?,
                        n_experts: c.usize_field("n_experts")?,
                        top_k: c.usize_field("top_k")?,
                        d_ffn: c.usize_field("d_ffn")?,
                        capacity_factor: c
                            .get("capacity_factor")
                            .and_then(|x| x.as_f64())
                            .unwrap_or(1.0),
                    },
                    params_total: v.usize_field("params_total")?,
                    params_activated: v.usize_field("params_activated")?,
                    param_specs: leaf_specs(
                        v.get("param_specs")
                            .ok_or_else(|| anyhow!("missing param_specs"))?,
                    )?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in root
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a.str_field("name")?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file: dir.join(a.str_field("file")?),
                    kind: a.str_field("kind")?,
                    args: leaf_specs(a.get("args").ok_or_else(|| anyhow!("missing args"))?)?,
                    results: leaf_specs(
                        a.get("results").ok_or_else(|| anyhow!("missing results"))?,
                    )?,
                    meta: a
                        .get("meta")
                        .and_then(|m| m.as_obj())
                        .cloned()
                        .unwrap_or_default(),
                },
            );
        }

        Ok(Manifest { dir, variants, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (re-run `make artifacts`?)"))
    }

    pub fn variant(&self, tag: &str) -> Result<&Variant> {
        self.variants
            .get(tag)
            .ok_or_else(|| anyhow!("variant {tag:?} not in manifest"))
    }

    /// All artifacts of a kind, e.g. every `train_step`.
    pub fn by_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactSpec> {
        self.artifacts.values().filter(move |a| a.kind == kind)
    }

    /// Find an artifact by kind + meta filters (variant/batch/seq...).
    pub fn find(
        &self,
        kind: &str,
        filters: &[(&str, &str)],
    ) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| {
                a.kind == kind
                    && filters.iter().all(|(k, want)| {
                        a.meta.get(*k).is_some_and(|v| match v {
                            Json::Str(s) => s == want,
                            Json::Num(n) => {
                                want.parse::<f64>().is_ok_and(|w| (*n - w).abs() < 1e-9)
                            }
                            _ => false,
                        })
                    })
            })
            .ok_or_else(|| anyhow!("no {kind:?} artifact matching {filters:?}"))
    }
}
