//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos with 64-bit instruction ids).
//!
//! A `Runtime` owns one PJRT client plus a compile cache.  PJRT wrapper
//! types hold raw pointers (not `Send`), so in multi-worker simulations
//! each worker thread builds its own `Runtime`; workers exchange only
//! host `Tensor`s through the collectives layer.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

pub use manifest::{ArtifactSpec, LeafSpec, Manifest, ModelConfig, Variant};

use crate::tensor::{Bundle, Tensor};

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns the flattened result tuple.
    pub fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            args.len() == self.spec.args.len(),
            "{}: got {} args, artifact wants {}",
            self.spec.name, args.len(), self.spec.args.len()
        );
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = out.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Execute with a leading parameter bundle + extra tensors (the common
    /// calling convention of model artifacts).
    pub fn run_bundled(&self, bundles: &[&Bundle], rest: &[&Tensor]) -> Result<Vec<Tensor>> {
        let mut args: Vec<&Tensor> = Vec::new();
        for b in bundles {
            args.extend(b.tensors.iter());
        }
        args.extend(rest.iter().copied());
        self.run(&args)
    }
}

pub struct Runtime {
    pub manifest: Rc<Manifest>,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// cumulative artifact-compile wall time (perf accounting)
    pub compile_secs: RefCell<f64>,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Rc::new(Manifest::load(artifacts_dir)?);
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    pub fn with_manifest(manifest: Rc<Manifest>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    /// Load (compile-once, cached) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name:?}"))?;
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Initialize a variant's parameters by executing its `init_*` artifact.
    pub fn init_params(&self, tag: &str, seed: i32) -> Result<Bundle> {
        let exe = self.load(&format!("init_{tag}"))?;
        let seed_t = Tensor::scalar_i32(seed);
        Ok(Bundle::new(exe.run(&[&seed_t])?))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
