//! Device mesh: maps flat worker ranks onto the paper's five parallelism
//! axes (DP × SP × PP × TP × EP, §2.2.3 "Hybrid Parallelism").
//!
//! Axis order (slowest- to fastest-varying): dp, sp, pp, tp, ep.
//! Subgroups along one axis are the set of ranks that agree on every other
//! coordinate -- e.g. the EP group of a rank is used for the MoE
//! all-to-all, the SP group for the LASP AllGather.

use anyhow::{ensure, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MeshShape {
    pub dp: usize,
    pub sp: usize,
    pub pp: usize,
    pub tp: usize,
    pub ep: usize,
}

impl MeshShape {
    pub fn new(dp: usize, sp: usize, pp: usize, tp: usize, ep: usize) -> Self {
        MeshShape { dp, sp, pp, tp, ep }
    }

    pub fn world(&self) -> usize {
        self.dp * self.sp * self.pp * self.tp * self.ep
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coords {
    pub dp: usize,
    pub sp: usize,
    pub pp: usize,
    pub tp: usize,
    pub ep: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    Dp,
    Sp,
    Pp,
    Tp,
    Ep,
}

#[derive(Clone, Debug)]
pub struct DeviceMesh {
    pub shape: MeshShape,
}

impl DeviceMesh {
    pub fn new(shape: MeshShape, world: usize) -> Result<DeviceMesh> {
        ensure!(
            shape.world() == world,
            "mesh {:?} needs {} workers, got {}",
            shape,
            shape.world(),
            world
        );
        Ok(DeviceMesh { shape })
    }

    pub fn world(&self) -> usize {
        self.shape.world()
    }

    /// rank -> coordinates (row-major over [dp, sp, pp, tp, ep]).
    pub fn coords(&self, rank: usize) -> Coords {
        let s = &self.shape;
        let mut r = rank;
        let ep = r % s.ep;
        r /= s.ep;
        let tp = r % s.tp;
        r /= s.tp;
        let pp = r % s.pp;
        r /= s.pp;
        let sp = r % s.sp;
        r /= s.sp;
        let dp = r % s.dp;
        Coords { dp, sp, pp, tp, ep }
    }

    /// coordinates -> rank.
    pub fn rank(&self, c: Coords) -> usize {
        let s = &self.shape;
        (((c.dp * s.sp + c.sp) * s.pp + c.pp) * s.tp + c.tp) * s.ep + c.ep
    }

    fn axis_size(&self, axis: Axis) -> usize {
        match axis {
            Axis::Dp => self.shape.dp,
            Axis::Sp => self.shape.sp,
            Axis::Pp => self.shape.pp,
            Axis::Tp => self.shape.tp,
            Axis::Ep => self.shape.ep,
        }
    }

    /// Ranks in `rank`'s subgroup along `axis`, ordered by that axis
    /// coordinate.  `rank` is always a member.
    pub fn axis_group(&self, rank: usize, axis: Axis) -> Vec<usize> {
        let base = self.coords(rank);
        (0..self.axis_size(axis))
            .map(|i| {
                let mut c = base;
                match axis {
                    Axis::Dp => c.dp = i,
                    Axis::Sp => c.sp = i,
                    Axis::Pp => c.pp = i,
                    Axis::Tp => c.tp = i,
                    Axis::Ep => c.ep = i,
                }
                self.rank(c)
            })
            .collect()
    }

    /// Index of `rank` within its `axis` subgroup.
    pub fn axis_index(&self, rank: usize, axis: Axis) -> usize {
        let c = self.coords(rank);
        match axis {
            Axis::Dp => c.dp,
            Axis::Sp => c.sp,
            Axis::Pp => c.pp,
            Axis::Tp => c.tp,
            Axis::Ep => c.ep,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{check, Rng};

    #[test]
    fn roundtrip_all_ranks() {
        let mesh = DeviceMesh::new(MeshShape::new(2, 1, 2, 1, 2), 8).unwrap();
        for r in 0..8 {
            assert_eq!(mesh.rank(mesh.coords(r)), r);
        }
    }

    #[test]
    fn axis_groups_partition_world() {
        // property: for any mesh shape, groups along each axis partition
        // the world and each rank appears in exactly one group per axis.
        check("axis_groups_partition", 32, |rng: &mut Rng| {
            let dims: Vec<usize> = (0..5).map(|_| 1 << rng.below(3)).collect();
            let shape = MeshShape::new(dims[0], dims[1], dims[2], dims[3], dims[4]);
            let mesh = DeviceMesh::new(shape, shape.world()).unwrap();
            for axis in [Axis::Dp, Axis::Sp, Axis::Pp, Axis::Tp, Axis::Ep] {
                let mut seen = vec![0usize; mesh.world()];
                for r in 0..mesh.world() {
                    let g = mesh.axis_group(r, axis);
                    assert!(g.contains(&r));
                    assert_eq!(g[mesh.axis_index(r, axis)], r);
                    for m in g {
                        seen[m] += 1;
                    }
                }
                // each rank appears axis_size times (once per group member)
                for (r, &cnt) in seen.iter().enumerate() {
                    assert_eq!(cnt, mesh.axis_size(axis), "rank {r} axis {axis:?}");
                }
            }
        });
    }

    #[test]
    fn mismatched_world_rejected() {
        assert!(DeviceMesh::new(MeshShape::new(2, 1, 2, 1, 2), 7).is_err());
    }
}
