//! Deterministic fault injection for the training system.
//!
//! Production MoE trainers treat rank failure, stragglers, and checkpoint
//! corruption as first-class events; this module makes those events
//! *reproducible* so the recovery machinery (timeout-aware collectives,
//! `run_ddp_resilient`, checkpoint rollback) can be tested exactly the way
//! normal numerics are.
//!
//! A [`FaultPlan`] is a set of one-shot faults, each addressed to a
//! (rank, step) coordinate:
//!  - `KillRank`: the rank panics inside its next collective at that step
//!    (the board is poisoned first so peers fail fast instead of timing
//!    out),
//!  - `DelayCollective`: the rank sleeps before the collective (straggler
//!    simulation; peers see latency, or a timeout if the delay exceeds the
//!    deadline),
//!  - `DropRing`: the rank's ring send at that step is silently discarded
//!    (the receiver's `ring_recv` deadline fires),
//!  - `CorruptCheckpoint`: flip one byte of the checkpoint file written at
//!    that step (exercises the CRC path; applied by the checkpoint layer).
//!
//! Every fault fires **once** per plan instance -- after a recovery the
//! replayed steps do not re-trigger it, which is what lets a killed run
//! resume and complete.  Plans are built from a spec string (CLI `--fault`)
//! or generated from a seed, so a failing scenario is a single token to
//! reproduce.

use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{bail, Context, Result};

// ---------------------------------------------------------------------------
// Shared CLI fault grammar: `kind:key=val,key=val;kind:...`.  The training
// plan (`FaultPlan::parse`) and the serving plan
// (`crate::serve::fault::ServeFaultPlan::parse`) both build on this, so the
// two `--fault` flags read identically.
// ---------------------------------------------------------------------------

/// One parsed `kind:key=val,...` clause of a fault spec.
#[derive(Clone, Debug)]
pub struct Clause {
    pub kind: String,
    keys: Vec<(String, u64)>,
    /// the raw clause text, kept for error messages
    text: String,
}

impl Clause {
    pub fn get(&self, key: &str) -> Option<u64> {
        self.keys.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }

    /// Required integer argument.
    pub fn need(&self, key: &str) -> Result<u64> {
        self.get(key)
            .with_context(|| format!("fault clause {:?}: missing {key}", self.text))
    }

    /// Reject keys outside `allowed` (catches typos like `rnak=`).
    pub fn allow(&self, allowed: &[&str]) -> Result<()> {
        for (k, _) in &self.keys {
            if !allowed.contains(&k.as_str()) {
                bail!("fault clause {:?}: unknown key {k:?}", self.text);
            }
        }
        Ok(())
    }
}

/// Split a `;`-separated fault spec into typed clauses.  Empty clauses are
/// skipped; every value must be a non-negative integer.
pub fn parse_clauses(spec: &str) -> Result<Vec<Clause>> {
    let mut out = Vec::new();
    for clause in spec.split(';').filter(|c| !c.trim().is_empty()) {
        let (kind, rest) = clause
            .split_once(':')
            .with_context(|| format!("fault clause {clause:?}: missing ':'"))?;
        let mut keys = Vec::new();
        for kv in rest.split(',').filter(|c| !c.trim().is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("fault clause {clause:?}: bad key=value {kv:?}"))?;
            let v: u64 = v
                .trim()
                .parse()
                .with_context(|| format!("fault clause {clause:?}: non-integer {kv:?}"))?;
            keys.push((k.trim().to_string(), v));
        }
        out.push(Clause {
            kind: kind.trim().to_string(),
            keys,
            text: clause.to_string(),
        });
    }
    Ok(out)
}

/// One injectable fault, addressed by rank and training step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic inside the next collective issued by `rank` at `step`.
    KillRank { rank: usize, step: usize },
    /// Sleep `ms` before the next collective issued by `rank` at `step`.
    DelayCollective { rank: usize, step: usize, ms: u64 },
    /// Silently drop the ring message sent by `rank` at `step`.
    DropRing { rank: usize, step: usize },
    /// Flip the byte at `offset` (mod file length) of the next checkpoint
    /// written while the plan is active.
    CorruptCheckpoint { offset: usize },
}

impl Fault {
    fn coords(&self) -> Option<(usize, usize)> {
        match *self {
            Fault::KillRank { rank, step } => Some((rank, step)),
            Fault::DelayCollective { rank, step, .. } => Some((rank, step)),
            Fault::DropRing { rank, step } => Some((rank, step)),
            Fault::CorruptCheckpoint { .. } => None,
        }
    }
}

/// A deterministic set of one-shot faults.  Shared (via `Arc`) between the
/// supervisor, every `CommHandle`, and the checkpoint writer.
#[derive(Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    fired: Vec<AtomicBool>,
}

impl FaultPlan {
    /// The empty plan: injects nothing, costs one branch per collective.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn new(faults: Vec<Fault>) -> Self {
        let fired = faults.iter().map(|_| AtomicBool::new(false)).collect();
        FaultPlan { faults, fired }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Parse a `--fault` spec: semicolon-separated clauses of
    /// `kill:rank=R,step=S` | `delay:rank=R,step=S,ms=D` |
    /// `drop_ring:rank=R,step=S` | `corrupt_ckpt:offset=B`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut faults = Vec::new();
        for c in parse_clauses(spec)? {
            let fault = match c.kind.as_str() {
                "kill" => {
                    c.allow(&["rank", "step"])?;
                    Fault::KillRank {
                        rank: c.need("rank")? as usize,
                        step: c.need("step")? as usize,
                    }
                }
                "delay" => {
                    c.allow(&["rank", "step", "ms"])?;
                    Fault::DelayCollective {
                        rank: c.need("rank")? as usize,
                        step: c.need("step")? as usize,
                        ms: c.need("ms")?,
                    }
                }
                "drop_ring" => {
                    c.allow(&["rank", "step"])?;
                    Fault::DropRing {
                        rank: c.need("rank")? as usize,
                        step: c.need("step")? as usize,
                    }
                }
                "corrupt_ckpt" => {
                    c.allow(&["offset"])?;
                    Fault::CorruptCheckpoint { offset: c.need("offset")? as usize }
                }
                other => bail!("unknown fault kind {other:?}"),
            };
            faults.push(fault);
        }
        Ok(FaultPlan::new(faults))
    }

    /// Seeded scenario generator: one kill of a random rank at a random
    /// step in `[1, steps)`, for soak-style testing (`--fault seed=N` is
    /// spelled by the caller; this is the library entry point).
    pub fn random_kill(seed: u64, world: usize, steps: usize) -> Self {
        let mut rng = crate::rng::Rng::new(seed);
        let rank = rng.below(world.max(1));
        let step = if steps > 1 { 1 + rng.below(steps - 1) } else { 0 };
        FaultPlan::new(vec![Fault::KillRank { rank, step }])
    }

    /// Atomically claim the first unfired fault matching `pred`.  Returns
    /// the fault exactly once across all threads/attempts.
    fn take(&self, pred: impl Fn(&Fault) -> bool) -> Option<Fault> {
        for (i, f) in self.faults.iter().enumerate() {
            if pred(f)
                && self.fired[i]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                return Some(*f);
            }
        }
        None
    }

    /// Claim a kill or delay addressed to (rank, step).  Called by
    /// `CommHandle` on entry to every collective.
    pub fn take_collective(&self, rank: usize, step: usize) -> Option<Fault> {
        self.take(|f| {
            matches!(f, Fault::KillRank { .. } | Fault::DelayCollective { .. })
                && f.coords() == Some((rank, step))
        })
    }

    /// Claim a ring-drop addressed to (rank, step).
    pub fn take_drop_ring(&self, rank: usize, step: usize) -> Option<Fault> {
        self.take(|f| matches!(f, Fault::DropRing { .. }) && f.coords() == Some((rank, step)))
    }

    /// Claim a checkpoint corruption (any pending one).
    pub fn take_corrupt_ckpt(&self) -> Option<Fault> {
        self.take(|f| matches!(f, Fault::CorruptCheckpoint { .. }))
    }

    /// Number of faults already fired (observability).
    pub fn fired_count(&self) -> usize {
        self.fired.iter().filter(|f| f.load(Ordering::Acquire)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse(
            "kill:rank=1,step=5;delay:rank=0,step=3,ms=50;drop_ring:rank=2,step=4;corrupt_ckpt:offset=7",
        )
        .unwrap();
        assert_eq!(
            p.faults(),
            &[
                Fault::KillRank { rank: 1, step: 5 },
                Fault::DelayCollective { rank: 0, step: 3, ms: 50 },
                Fault::DropRing { rank: 2, step: 4 },
                Fault::CorruptCheckpoint { offset: 7 },
            ]
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("kill").is_err());
        assert!(FaultPlan::parse("kill:rank=1").is_err()); // missing step
        assert!(FaultPlan::parse("explode:rank=1,step=2").is_err());
        assert!(FaultPlan::parse("kill:rank=x,step=2").is_err());
        assert!(FaultPlan::parse("delay:rank=0,step=1").is_err()); // missing ms
    }

    #[test]
    fn shared_clause_grammar() {
        let cs = parse_clauses("kill:rank=1,step=5; delay:rank=0,step=3,ms=50").unwrap();
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].kind, "kill");
        assert_eq!(cs[0].get("rank"), Some(1));
        assert_eq!(cs[0].get("nope"), None);
        assert!(cs[0].need("nope").is_err());
        assert!(cs[0].allow(&["rank", "step"]).is_ok());
        assert!(cs[0].allow(&["rank"]).is_err());
        // typo'd keys are rejected by the consumers
        assert!(FaultPlan::parse("kill:rnak=1,step=2").is_err());
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn faults_fire_exactly_once() {
        let p = FaultPlan::parse("kill:rank=1,step=5").unwrap();
        assert!(p.take_collective(0, 5).is_none());
        assert!(p.take_collective(1, 4).is_none());
        assert_eq!(
            p.take_collective(1, 5),
            Some(Fault::KillRank { rank: 1, step: 5 })
        );
        // one-shot: replaying the same (rank, step) after recovery is clean
        assert!(p.take_collective(1, 5).is_none());
        assert_eq!(p.fired_count(), 1);
    }

    #[test]
    fn random_kill_is_deterministic_and_in_range() {
        let a = FaultPlan::random_kill(9, 4, 10);
        let b = FaultPlan::random_kill(9, 4, 10);
        assert_eq!(a.faults(), b.faults());
        match a.faults()[0] {
            Fault::KillRank { rank, step } => {
                assert!(rank < 4);
                assert!((1..10).contains(&step));
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
