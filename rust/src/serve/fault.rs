//! Deterministic fault injection for the serving engine, plus the CRC
//! integrity layer on lane-state images.
//!
//! This is the serving counterpart of `crate::fault` (training): the same
//! one-shot, coordinate-addressed design and the same CLI clause grammar
//! (`crate::fault::parse_clauses`), so a failing serving scenario is one
//! `--fault` string to reproduce.  A [`ServeFaultPlan`] holds:
//!
//!  - `StepError { step, lane }`: the `step`-th `decode_step` attempt
//!    fails with a typed [`ServeFaultError::Step`] naming a victim lane.
//!    The wrapper errors *before* touching the inner decoder, modeling a
//!    backend launch failure: no lane's state advanced, so the engine can
//!    retire or re-prefill the victim and retry the batch next tick.
//!  - `CorruptState { req, byte }`: flip one bit of request `req`'s next
//!    saved lane-state image *after* the engine stamps its CRC -- bit-rot
//!    in the swapped-out image.  The engine must detect it on resume and
//!    re-prefill instead of decoding from garbage.
//!  - `Stall { step, ticks }`: `decode_step` reports
//!    [`ServeFaultError::Stall`] for `ticks` consecutive attempts -- a hung
//!    backend.  The engine burns ticks (deadlines keep running) without
//!    advancing any lane.
//!
//! Injection points split by what they model: [`FaultDecoder`] wraps any
//! `Decoder` and claims step errors and stalls at the decode boundary;
//! the engine itself claims state corruption, because corruption must
//! land between CRC stamping and CRC verification to exercise the
//! integrity path (a flip before stamping would be checksummed in).
//!
//! The CRC helpers hash a `LaneState` image the way checkpoint format v2
//! hashes files (`checkpoint::Crc32`, streaming -- no staging buffer):
//! dtype, rank, dims, and payload bits of every tensor, so shape-preserving
//! payload flips and shape edits are both caught.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::Crc32;
use crate::fault::parse_clauses;
use crate::inference::{Decoder, LaneState};
use crate::rng::Rng;
use crate::tensor::{Data, Tensor};

/// One injectable serving fault (see module docs for semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeFault {
    /// Fail the `step`-th `decode_step` attempt, blaming `lane`.
    StepError { step: u64, lane: usize },
    /// Flip one bit of request `req`'s next saved lane-state image, at
    /// byte offset `byte` (mod image size).
    CorruptState { req: u64, byte: usize },
    /// Starting at the `step`-th `decode_step` attempt, stall for `ticks`
    /// attempts.
    Stall { step: u64, ticks: u64 },
}

/// Typed error surfaced by [`FaultDecoder::decode_step`]; the engine
/// downcasts (`anyhow::Error::downcast_ref`) to tell injected faults from
/// real backend failures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeFaultError {
    /// Decode-step failure attributed to one victim lane.
    Step { lane: usize },
    /// The backend is stalled; no lane advanced this tick.
    Stall,
}

impl std::fmt::Display for ServeFaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeFaultError::Step { lane } => {
                write!(f, "injected decoder step error on lane {lane}")
            }
            ServeFaultError::Stall => write!(f, "injected decoder stall"),
        }
    }
}

impl std::error::Error for ServeFaultError {}

/// A deterministic set of one-shot serving faults.  Shared (via `Arc`)
/// between the [`FaultDecoder`] wrapper (step errors, stalls) and the
/// engine (state corruption).
#[derive(Debug, Default)]
pub struct ServeFaultPlan {
    faults: Vec<ServeFault>,
    fired: Vec<AtomicBool>,
}

impl ServeFaultPlan {
    /// The empty plan: injects nothing.
    pub fn none() -> Self {
        ServeFaultPlan::default()
    }

    pub fn new(faults: Vec<ServeFault>) -> Self {
        let fired = faults.iter().map(|_| AtomicBool::new(false)).collect();
        ServeFaultPlan { faults, fired }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[ServeFault] {
        &self.faults
    }

    /// Parse a serving `--fault` spec (shared clause grammar):
    /// `step_err:step=S,lane=L` | `corrupt_state:req=R[,byte=B]` |
    /// `stall:step=S,ticks=N`, `;`-separated.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut faults = Vec::new();
        for c in parse_clauses(spec)? {
            let fault = match c.kind.as_str() {
                "step_err" => {
                    c.allow(&["step", "lane"])?;
                    ServeFault::StepError {
                        step: c.need("step")?,
                        lane: c.need("lane")? as usize,
                    }
                }
                "corrupt_state" => {
                    c.allow(&["req", "byte"])?;
                    ServeFault::CorruptState {
                        req: c.need("req")?,
                        byte: c.get("byte").unwrap_or(0) as usize,
                    }
                }
                "stall" => {
                    c.allow(&["step", "ticks"])?;
                    ServeFault::Stall {
                        step: c.need("step")?,
                        ticks: c.need("ticks")?.max(1),
                    }
                }
                other => bail!("unknown serving fault kind {other:?}"),
            };
            faults.push(fault);
        }
        Ok(ServeFaultPlan::new(faults))
    }

    /// Seeded soak-style generator: step errors drawn Bernoulli(`rate`)
    /// per decode attempt over `horizon` attempts, each blaming a random
    /// lane in `[0, lanes)`.  Deterministic in `seed`; `rate = 0` is the
    /// empty plan.  This drives the bench fault-rate sweep.
    pub fn seeded_step_errors(seed: u64, horizon: u64, lanes: usize, rate: f64) -> Self {
        let mut rng = Rng::new(seed);
        let mut faults = Vec::new();
        for step in 0..horizon {
            // draw both variates unconditionally so the fault coordinates
            // at a given step do not depend on `rate`
            let u = rng.f32() as f64;
            let lane = rng.below(lanes.max(1));
            if u < rate {
                faults.push(ServeFault::StepError { step, lane });
            }
        }
        ServeFaultPlan::new(faults)
    }

    /// Atomically claim the first unfired fault matching `pred` (fires
    /// exactly once across all claimants).
    fn take(&self, pred: impl Fn(&ServeFault) -> bool) -> Option<ServeFault> {
        for (i, f) in self.faults.iter().enumerate() {
            if pred(f)
                && self.fired[i]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                return Some(*f);
            }
        }
        None
    }

    /// Claim a step error addressed to decode attempt `step`.
    pub fn take_step_error(&self, step: u64) -> Option<ServeFault> {
        self.take(|f| matches!(f, ServeFault::StepError { step: s, .. } if *s == step))
    }

    /// Claim a stall starting at decode attempt `step`.
    pub fn take_stall(&self, step: u64) -> Option<ServeFault> {
        self.take(|f| matches!(f, ServeFault::Stall { step: s, .. } if *s == step))
    }

    /// Claim a state corruption addressed to request `req` (called by the
    /// engine right after stamping the image CRC).
    pub fn take_corrupt_state(&self, req: u64) -> Option<ServeFault> {
        self.take(|f| matches!(f, ServeFault::CorruptState { req: r, .. } if *r == req))
    }

    /// Number of faults already fired (observability).
    pub fn fired_count(&self) -> usize {
        self.fired.iter().filter(|f| f.load(Ordering::Acquire)).count()
    }
}

// ---------------------------------------------------------------------------
// Fault-wrapping decoder adapter.
// ---------------------------------------------------------------------------

/// Wraps any [`Decoder`] and injects the plan's step errors and stalls at
/// the `decode_step` boundary.  All state operations delegate untouched
/// (state corruption is the engine's injection point, after CRC stamping).
/// The attempt counter ticks on *every* `decode_step` call, including
/// injected failures, so fault coordinates are deterministic under any
/// interleaving.
pub struct FaultDecoder<D: Decoder> {
    inner: D,
    plan: Arc<ServeFaultPlan>,
    /// decode attempts so far (== the `step` coordinate faults address)
    step: u64,
    stall_left: u64,
    pub injected_step_errors: u64,
    pub injected_stall_ticks: u64,
}

impl<D: Decoder> FaultDecoder<D> {
    pub fn new(inner: D, plan: Arc<ServeFaultPlan>) -> Self {
        FaultDecoder {
            inner,
            plan,
            step: 0,
            stall_left: 0,
            injected_step_errors: 0,
            injected_stall_ticks: 0,
        }
    }

    pub fn into_inner(self) -> D {
        self.inner
    }
}

impl<D: Decoder> Decoder for FaultDecoder<D> {
    fn lanes(&self) -> usize {
        self.inner.lanes()
    }

    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor> {
        let step = self.step;
        self.step += 1;
        if self.stall_left == 0 {
            if let Some(ServeFault::Stall { ticks, .. }) = self.plan.take_stall(step) {
                self.stall_left = ticks;
            }
        }
        if self.stall_left > 0 {
            self.stall_left -= 1;
            self.injected_stall_ticks += 1;
            return Err(ServeFaultError::Stall.into());
        }
        if let Some(ServeFault::StepError { lane, .. }) = self.plan.take_step_error(step) {
            self.injected_step_errors += 1;
            return Err(ServeFaultError::Step { lane }.into());
        }
        self.inner.decode_step(tokens, pos)
    }

    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()> {
        self.inner.save_lane(lane, out)
    }

    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()> {
        self.inner.load_lane(lane, src)
    }

    fn reset_lane(&mut self, lane: usize) -> Result<()> {
        self.inner.reset_lane(lane)
    }

    fn lane_state_bytes(&self, pos: usize) -> usize {
        self.inner.lane_state_bytes(pos)
    }

    fn aligned_lanes_only(&self) -> bool {
        self.inner.aligned_lanes_only()
    }
}

// ---------------------------------------------------------------------------
// Lane-state image integrity (the checkpoint-v2 CRC approach, in RAM).
// ---------------------------------------------------------------------------

/// CRC-32 over a lane-state image: per tensor, dtype tag, rank, dims, and
/// the exact payload bits (f32 via `to_bits`, so any stored-bit flip --
/// including NaN-payload and signed-zero changes -- alters the digest).
/// Streaming: allocates nothing.
pub fn lane_state_crc(st: &LaneState) -> u32 {
    let mut h = Crc32::new();
    for t in &st.tensors {
        h.update(&[t.is_f32() as u8]);
        h.update(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            h.update(&(d as u64).to_le_bytes());
        }
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    h.update(&x.to_bits().to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    h.update(&x.to_le_bytes());
                }
            }
        }
    }
    h.finish()
}

/// Flip one bit of the element containing byte `byte` (mod payload size).
/// Returns false when the image has no payload to corrupt.
pub fn corrupt_lane_state(st: &mut LaneState, byte: usize) -> bool {
    let total: usize = st.tensors.iter().map(Tensor::size_bytes).sum();
    if total == 0 {
        return false;
    }
    let mut off = byte % total;
    for t in &mut st.tensors {
        let sz = t.size_bytes();
        if off >= sz {
            off -= sz;
            continue;
        }
        let elem = off / 4;
        match &mut t.data {
            Data::F32(v) => v[elem] = f32::from_bits(v[elem].to_bits() ^ 1),
            Data::I32(v) => v[elem] ^= 1,
        }
        return true;
    }
    unreachable!("offset reduced below total payload size")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::refmodel::RefLsmDecoder;

    #[test]
    fn parses_serving_grammar() {
        let p = ServeFaultPlan::parse(
            "step_err:step=30,lane=1;corrupt_state:req=3;stall:step=50,ticks=20;\
             corrupt_state:req=7,byte=9",
        )
        .unwrap();
        assert_eq!(
            p.faults(),
            &[
                ServeFault::StepError { step: 30, lane: 1 },
                ServeFault::CorruptState { req: 3, byte: 0 },
                ServeFault::Stall { step: 50, ticks: 20 },
                ServeFault::CorruptState { req: 7, byte: 9 },
            ]
        );
        assert!(ServeFaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ServeFaultPlan::parse("step_err").is_err());
        assert!(ServeFaultPlan::parse("step_err:step=1").is_err()); // missing lane
        assert!(ServeFaultPlan::parse("step_err:step=1,lane=0,rank=2").is_err());
        assert!(ServeFaultPlan::parse("corrupt_state:byte=3").is_err()); // missing req
        assert!(ServeFaultPlan::parse("stall:step=x,ticks=2").is_err());
        assert!(ServeFaultPlan::parse("explode:step=1").is_err());
    }

    #[test]
    fn faults_fire_exactly_once() {
        let p = ServeFaultPlan::parse("step_err:step=5,lane=0;corrupt_state:req=2").unwrap();
        assert!(p.take_step_error(4).is_none());
        assert_eq!(
            p.take_step_error(5),
            Some(ServeFault::StepError { step: 5, lane: 0 })
        );
        assert!(p.take_step_error(5).is_none(), "one-shot");
        assert!(p.take_corrupt_state(1).is_none());
        assert!(p.take_corrupt_state(2).is_some());
        assert!(p.take_corrupt_state(2).is_none());
        assert_eq!(p.fired_count(), 2);
    }

    #[test]
    fn seeded_step_errors_deterministic_and_rate_scaled() {
        let a = ServeFaultPlan::seeded_step_errors(3, 1000, 4, 0.05);
        let b = ServeFaultPlan::seeded_step_errors(3, 1000, 4, 0.05);
        assert_eq!(a.faults(), b.faults());
        assert!(ServeFaultPlan::seeded_step_errors(3, 1000, 4, 0.0).is_empty());
        let lo = ServeFaultPlan::seeded_step_errors(3, 1000, 4, 0.01).faults().len();
        let hi = a.faults().len();
        assert!(hi > lo, "5% plan ({hi}) must inject more than 1% ({lo})");
        assert!(hi >= 20 && hi <= 110, "rate wildly off: {hi} faults in 1000 steps");
        for f in a.faults() {
            match *f {
                ServeFault::StepError { step, lane } => {
                    assert!(step < 1000 && lane < 4);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // higher-rate plan is a superset of the lower-rate plan at the
        // same seed (coordinates are rate-invariant)
        let lo_plan = ServeFaultPlan::seeded_step_errors(3, 1000, 4, 0.01);
        for f in lo_plan.faults() {
            assert!(a.faults().contains(f), "{f:?} missing at higher rate");
        }
    }

    #[test]
    fn fault_decoder_injects_then_delegates() {
        let plan = Arc::new(
            ServeFaultPlan::parse("step_err:step=1,lane=0;stall:step=3,ticks=2").unwrap(),
        );
        let mut dec = FaultDecoder::new(RefLsmDecoder::new(1, 16, 4, 7), plan);
        let tok = Tensor::i32(&[1], vec![3]);
        let mut ok_logits = Vec::new();
        ok_logits.push(dec.decode_step(&tok, &[0]).expect("step 0 clean"));
        let err = dec.decode_step(&tok, &[1]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ServeFaultError>(),
            Some(&ServeFaultError::Step { lane: 0 })
        );
        ok_logits.push(dec.decode_step(&tok, &[1]).expect("step 2 clean"));
        for attempt in 0..2 {
            let err = dec.decode_step(&tok, &[2]).unwrap_err();
            assert_eq!(
                err.downcast_ref::<ServeFaultError>(),
                Some(&ServeFaultError::Stall),
                "stall attempt {attempt}"
            );
        }
        ok_logits.push(dec.decode_step(&tok, &[2]).expect("stall over"));
        assert_eq!(dec.injected_step_errors, 1);
        assert_eq!(dec.injected_stall_ticks, 2);
        // injected failures never touched inner state: the successful
        // steps match a clean decoder fed the same token sequence
        let mut clean = RefLsmDecoder::new(1, 16, 4, 7);
        for (p, got) in ok_logits.iter().enumerate() {
            let want = clean.decode_step(&tok, &[p as i32]).unwrap();
            assert_eq!(got.as_f32().unwrap(), want.as_f32().unwrap(), "step {p}");
        }
    }

    #[test]
    fn crc_detects_any_single_bit_flip() {
        let mut st = LaneState::default();
        st.slot(0, &[3], true).as_f32_mut().unwrap().copy_from_slice(&[1.0, -2.5, 0.0]);
        st.slot(1, &[2], false).as_i32_mut().unwrap().copy_from_slice(&[7, -9]);
        st.tensors.truncate(2);
        let clean = lane_state_crc(&st);
        assert_eq!(clean, lane_state_crc(&st), "digest is a pure function");
        let total: usize = st.tensors.iter().map(Tensor::size_bytes).sum();
        for byte in 0..total {
            let mut copy = st.clone();
            assert!(corrupt_lane_state(&mut copy, byte));
            assert_ne!(lane_state_crc(&copy), clean, "flip at byte {byte} undetected");
        }
        // shape edits are caught too, not just payload flips
        let mut reshaped = st.clone();
        reshaped.tensors[0].shape = vec![1, 3];
        assert_ne!(lane_state_crc(&reshaped), clean);
        // empty images cannot be corrupted
        assert!(!corrupt_lane_state(&mut LaneState::default(), 0));
    }
}
