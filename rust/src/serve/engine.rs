//! Continuous-batching decode engine with fault supervision.
//!
//! A fixed-width batch of decode lanes is backed by a pool of per-request
//! sessions.  Each tick the engine ingests arrivals into the bounded
//! queue (backpressure), expires requests past their deadline, admits
//! sessions into idle lanes (preempted sessions resume first, FIFO), runs
//! one `Decoder` step for the whole batch, and retires or preempts lanes.
//! Prefill runs prompt tokens through the same step loop before a lane
//! goes live; admission of a fresh request is a zero-copy lane reset, and
//! state swaps go through the `StateArena` free-list so steady state
//! allocates nothing.
//!
//! Because per-lane computation is lane-independent (the `Decoder`
//! contract), every request's token stream is bitwise identical to
//! running it alone single-stream (`run_one`), whatever the interleaving.
//! The fault machinery preserves that guarantee:
//!
//!  - a failed `decode_step` ([`ServeFaultError::Step`]) happens *before*
//!    any lane advances, so non-victim lanes replay the identical step
//!    next tick; the victim is rewound to its prompt and re-prefilled
//!    (bounded by `max_retries`, then retired `Failed`),
//!  - every preempted lane-state image is CRC-stamped at check-out and
//!    verified at check-in; a corrupted image is never loaded -- the
//!    session replays from its prompt instead of decoding from garbage,
//!  - a stalled backend ([`ServeFaultError::Stall`]) burns engine ticks
//!    without advancing anyone, so deadlines keep running,
//!  - per-request deadlines (`Request::ttl`) expire queued, ready, and
//!    running sessions, and admission sheds requests that provably cannot
//!    finish in time instead of wasting lane steps on them.
//!
//! Replayed sessions regenerate the same stream (seeded samplers), so a
//! `Finished` result -- recovered or not -- is always bitwise equal to
//! `run_one`, and a partial result (`Failed`/`Expired`) is always a
//! prefix of it, never wrong tokens.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::ServeOutcomes;
use crate::inference::Decoder;
use crate::json::Json;
use crate::tensor::Tensor;
use crate::trace::{TraceHandle, Track};

use super::fault::{corrupt_lane_state, lane_state_crc, ServeFault, ServeFaultError,
                   ServeFaultPlan};
use super::queue::{Arrival, BoundedQueue, Request};
use super::session::{Session, StateArena};

/// Typed engine failures.  Invariant violations that used to abort the
/// process now surface through `run_trace` as values, so a supervisor can
/// retire one poisoned request while the rest of the batch keeps going.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// `retire`/`preempt` addressed a lane with no seated session.
    EmptyLane { lane: usize, op: &'static str },
    /// A live session past prefill has no sampled token to feed back.
    NoSampledToken { id: u64 },
    /// The decoder requires aligned lanes (one shared position, e.g. the
    /// scalar-pos PJRT attention path) but the engine schedules lanes at
    /// independent positions; rejected at construction.
    AlignedLanesOnly { lanes: usize },
    /// The trace exceeded the configured safety stop.
    MaxTicks { max: u64 },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::EmptyLane { lane, op } => {
                write!(f, "engine invariant: {op} on empty lane {lane}")
            }
            EngineError::NoSampledToken { id } => {
                write!(f, "engine invariant: request {id} past prefill with no sampled token")
            }
            EngineError::AlignedLanesOnly { lanes } => write!(
                f,
                "decoder only supports aligned lanes but the engine schedules {lanes} \
                 lanes at independent positions (run with batch 1 or a ragged-capable \
                 backend)"
            ),
            EngineError::MaxTicks { max } => {
                write!(f, "engine exceeded max_ticks ({max})")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// How a request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Full token stream produced (bitwise equal to `run_one`).
    Finished,
    /// Deadline passed while queued, ready, or running; tokens are a
    /// prefix of the reference stream.
    Expired,
    /// Refused at admission: could not possibly finish by its deadline.
    /// No lane steps were spent; no tokens.
    Shed,
    /// Decoder faults / corrupt state images exhausted the retry budget.
    Failed { retries: u32 },
}

#[derive(Clone, Debug)]
pub struct EngineCfg {
    /// queue depth before submissions bounce (backpressure)
    pub max_pending: usize,
    /// decode-step quantum after which a lane is swapped out for waiting
    /// work (None = run every request to completion)
    pub preempt_after: Option<u64>,
    /// safety stop for runaway traces
    pub max_ticks: u64,
    /// re-prefill replays allowed per request before it retires `Failed`
    pub max_retries: u32,
    /// deterministic fault plan (empty = inject nothing); shared with the
    /// `FaultDecoder` wrapper when one is in play
    pub fault: Arc<ServeFaultPlan>,
    /// trace sink for engine/request lifecycle spans (no-op by default).
    /// The engine is single-threaded and emits logical-tick timestamps
    /// only, so its whole trace is deterministic.
    pub trace: TraceHandle,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg {
            max_pending: 1024,
            preempt_after: None,
            max_ticks: 10_000_000,
            max_retries: 2,
            fault: Arc::new(ServeFaultPlan::none()),
            trace: TraceHandle::none(),
        }
    }
}

/// Final per-request record (ticks are engine steps, deterministic).
/// `admit_tick`/`first_token_tick` are `None` for requests that never
/// reached a lane or never sampled (shed, early expiry).
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub outcome: Outcome,
    pub tokens: Vec<i32>,
    pub arrival_tick: u64,
    pub admit_tick: Option<u64>,
    pub first_token_tick: Option<u64>,
    /// tick the request left the engine, whatever the outcome
    pub finish_tick: u64,
    /// absolute deadline (`arrival + ttl`), if the request had one
    pub deadline: Option<u64>,
    pub preemptions: u32,
    /// re-prefill replays performed (faults + corrupt-state recoveries)
    pub retries: u32,
}

impl RequestResult {
    /// Ticks spent queued before first entering a lane.
    pub fn queue_wait(&self) -> Option<u64> {
        self.admit_tick.map(|t| t - self.arrival_tick)
    }

    /// Time-to-first-token in ticks from arrival.
    pub fn ttft(&self) -> Option<u64> {
        self.first_token_tick.map(|t| t - self.arrival_tick)
    }

    /// Ticks past the deadline at retirement (None: no deadline, or made
    /// it in time -- note shed requests retire *before* their deadline).
    pub fn deadline_miss(&self) -> Option<u64> {
        let d = self.deadline?;
        (self.finish_tick > d).then(|| self.finish_tick - d)
    }
}

/// Run summary.  Every field except `wall_secs` is a deterministic
/// function of (trace, config, fault plan, decoder weights).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub results: Vec<RequestResult>,
    /// engine clock at the end of the trace
    pub ticks: u64,
    /// decoder step invocations that ran a batch (faulted attempts and
    /// stalled ticks are excluded)
    pub steps: u64,
    /// sum over steps of the number of live lanes
    pub active_lane_steps: u64,
    /// tokens of `Finished` requests only (goodput; partial streams of
    /// expired/failed requests do not count)
    pub tokens_out: u64,
    pub wall_secs: f64,
    /// state check-ins/outs (preemption swaps; fresh admits are resets)
    pub swaps: u64,
    pub swap_bytes: u64,
    /// LaneState buffer (re)allocations across the whole run
    pub state_reallocs: u64,
    /// bounced submit attempts (backpressure)
    pub rejected: u64,
    /// per-outcome request counts
    pub outcomes: ServeOutcomes,
    /// injected decode-step faults the engine absorbed
    pub faults_injected: u64,
    /// ticks burned by an injected backend stall
    pub stalled_ticks: u64,
    /// lane-state images that failed CRC verification at check-in
    pub crc_failures: u64,
    /// state corruptions the plan injected after CRC stamping
    pub corruptions_injected: u64,
    /// true when the CLI fell back from the requested backend (PJRT) to
    /// the reference decoder; the engine itself never sets this
    pub degraded: bool,
}

impl ServeReport {
    /// Mean live lanes per decoder step (> 1 means batching is paying).
    pub fn occupancy(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.active_lane_steps as f64 / self.steps as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.wall_secs
    }
}

pub struct Engine<D: Decoder> {
    pub dec: D,
    cfg: EngineCfg,
    queue: BoundedQueue<Session>,
    /// preempted/replaying sessions waiting to resume; served before
    /// fresh admits
    ready: VecDeque<Session>,
    lanes: Vec<Option<Session>>,
    arena: StateArena,
    tick: u64,
    steps: u64,
    active_lane_steps: u64,
    swaps: u64,
    swap_bytes: u64,
    outcomes: ServeOutcomes,
    faults_injected: u64,
    stalled_ticks: u64,
    crc_failures: u64,
    corruptions_injected: u64,
    /// any submitted request carried a TTL (skips expiry scans otherwise)
    has_deadlines: bool,
    results: Vec<RequestResult>,
}

impl<D: Decoder> Engine<D> {
    /// Rejects decoders that cannot serve ragged lanes (see
    /// [`EngineError::AlignedLanesOnly`]) unless they run single-lane,
    /// where every batch is trivially aligned.
    pub fn new(dec: D, cfg: EngineCfg) -> Result<Self> {
        if dec.aligned_lanes_only() && dec.lanes() > 1 {
            return Err(EngineError::AlignedLanesOnly { lanes: dec.lanes() }.into());
        }
        let lanes = (0..dec.lanes()).map(|_| None).collect();
        let queue = BoundedQueue::new(cfg.max_pending);
        Ok(Engine {
            dec,
            cfg,
            queue,
            ready: VecDeque::new(),
            lanes,
            arena: StateArena::default(),
            tick: 0,
            steps: 0,
            active_lane_steps: 0,
            swaps: 0,
            swap_bytes: 0,
            outcomes: ServeOutcomes::default(),
            faults_injected: 0,
            stalled_ticks: 0,
            crc_failures: 0,
            corruptions_injected: 0,
            has_deadlines: false,
            results: Vec::new(),
        })
    }

    fn req_track(id: u64) -> Track {
        Track::new("req", id)
    }

    /// Submit one request at the current tick; `Err` = backpressure.
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        debug_assert!(!req.prompt.is_empty() && req.max_new >= 1);
        self.has_deadlines |= req.ttl.is_some();
        let id = req.id;
        match self.queue.submit(Session::new(req, self.tick)) {
            Ok(()) => {
                if self.cfg.trace.on() {
                    self.cfg.trace.instant(
                        Self::req_track(id),
                        "serve",
                        "req.queued",
                        self.tick,
                        Vec::new(),
                    );
                }
                Ok(())
            }
            Err(s) => Err(s.req),
        }
    }

    /// Record a terminal outcome for a session (lane-held or not).
    fn finish(&mut self, mut s: Session, outcome: Outcome) {
        if let Some(st) = s.state.take() {
            self.arena.put(st);
        }
        match outcome {
            Outcome::Finished => {
                self.outcomes.finished += 1;
                if s.retries > 0 {
                    self.outcomes.recovered += 1;
                }
            }
            Outcome::Expired => self.outcomes.expired += 1,
            Outcome::Shed => self.outcomes.shed += 1,
            Outcome::Failed { .. } => self.outcomes.failed += 1,
        }
        if self.cfg.trace.on() {
            let outcome_str = match outcome {
                Outcome::Finished => "finished",
                Outcome::Expired => "expired",
                Outcome::Shed => "shed",
                Outcome::Failed { .. } => "failed",
            };
            let finish_tick = s.finish_tick.unwrap_or(self.tick);
            let mut args = s.trace_args();
            args.push(("outcome".to_string(), Json::from(outcome_str)));
            // The whole queued -> finished lifetime as one span, so a
            // request's story reads left-to-right on its own track.
            self.cfg.trace.span(
                Self::req_track(s.req.id),
                "serve",
                "req.lifecycle",
                s.arrival_tick,
                finish_tick.saturating_sub(s.arrival_tick),
                args,
            );
            self.cfg.trace.instant(
                Self::req_track(s.req.id),
                "serve",
                &format!("req.{outcome_str}"),
                finish_tick,
                Vec::new(),
            );
        }
        self.results.push(RequestResult {
            id: s.req.id,
            outcome,
            tokens: s.generated,
            arrival_tick: s.arrival_tick,
            admit_tick: s.admit_tick,
            first_token_tick: s.first_token_tick,
            finish_tick: s.finish_tick.unwrap_or(self.tick),
            deadline: s.deadline,
            preemptions: s.preemptions,
            retries: s.retries,
        });
    }

    /// Retire the session seated on `lane` with `outcome`.
    fn retire(&mut self, lane: usize, outcome: Outcome) -> Result<()> {
        let s = self.lanes[lane]
            .take()
            .ok_or(EngineError::EmptyLane { lane, op: "retire" })?;
        self.finish(s, outcome);
        Ok(())
    }

    /// Expire every session whose deadline has passed -- queued, ready,
    /// or running.  Partial tokens (a prefix of the reference stream) are
    /// kept in the result.
    fn expire(&mut self) {
        if !self.has_deadlines {
            return;
        }
        let tick = self.tick;
        let late = |s: &Session| s.deadline.is_some_and(|d| tick > d);
        for s in self.queue.extract(late) {
            self.finish(s, Outcome::Expired);
        }
        let mut i = 0;
        while i < self.ready.len() {
            match self.ready.remove(i) {
                Some(s) if late(&s) => self.finish(s, Outcome::Expired),
                Some(s) => {
                    self.ready.insert(i, s);
                    i += 1;
                }
                None => break,
            }
        }
        for lane in 0..self.lanes.len() {
            if self.lanes[lane].as_ref().is_some_and(late) {
                if let Some(s) = self.lanes[lane].take() {
                    self.finish(s, Outcome::Expired);
                }
            }
        }
    }

    /// Fill idle lanes: resume preempted sessions first (FIFO), then admit
    /// fresh requests with a zero-copy lane reset.  A lane loops until it
    /// seats a session or both sources run dry, because candidates can
    /// retire at the door (shed, retry budget spent).
    fn admit(&mut self) -> Result<()> {
        for lane in 0..self.lanes.len() {
            while self.lanes[lane].is_none() {
                if let Some(s) = self.ready.pop_front() {
                    self.resume(lane, s)?;
                } else if let Some(s) = self.queue.pop() {
                    self.admit_fresh(lane, s)?;
                } else {
                    break;
                }
            }
        }
        Ok(())
    }

    /// Seat a previously-run session.  Its saved state image is loaded
    /// only after passing the CRC check; a corrupted image is recycled
    /// unread and the session replays from its prompt (or retires
    /// `Failed` once the retry budget is spent).  A session with no state
    /// (fault replay) re-prefills on a reset lane.
    fn resume(&mut self, lane: usize, mut s: Session) -> Result<()> {
        if let Some(st) = s.state.take() {
            if lane_state_crc(&st) == s.state_crc {
                self.dec.load_lane(lane, &st)?;
                self.swaps += 1;
                self.swap_bytes += st.size_bytes() as u64;
                self.arena.put(st);
                if self.cfg.trace.on() {
                    self.cfg.trace.instant(
                        Self::req_track(s.req.id),
                        "serve",
                        "req.resume",
                        self.tick,
                        vec![
                            ("lane".to_string(), Json::from(lane)),
                            ("crc_ok".to_string(), Json::from(true)),
                        ],
                    );
                }
                self.seat(lane, s);
                return Ok(());
            }
            self.crc_failures += 1;
            self.arena.put(st);
            if self.cfg.trace.on() {
                self.cfg.trace.instant(
                    Self::req_track(s.req.id),
                    "fault",
                    "req.crc_fail",
                    self.tick,
                    vec![("lane".to_string(), Json::from(lane))],
                );
            }
            if s.retries >= self.cfg.max_retries {
                // budget spent: keep the partial stream (a prefix of the
                // reference -- the corrupted image was never decoded from)
                let retries = s.retries;
                self.finish(s, Outcome::Failed { retries });
                return Ok(());
            }
            s.rewind_for_replay();
        }
        self.dec.reset_lane(lane)?;
        self.seat(lane, s);
        Ok(())
    }

    /// Admit a fresh request, unless it provably cannot finish by its
    /// deadline even with a lane all to itself -- then shed it now rather
    /// than burn lane steps on a doomed request.
    fn admit_fresh(&mut self, lane: usize, s: Session) -> Result<()> {
        if let Some(d) = s.deadline {
            // finishing takes min_service_steps ticks starting now; the
            // last one lands at tick + steps - 1, which must be <= d
            if self.tick + s.req.min_service_steps() > d + 1 {
                self.finish(s, Outcome::Shed);
                return Ok(());
            }
        }
        self.dec.reset_lane(lane)?;
        self.seat(lane, s);
        Ok(())
    }

    fn seat(&mut self, lane: usize, mut s: Session) {
        if s.admit_tick.is_none() {
            s.admit_tick = Some(self.tick);
        }
        s.resident_steps = 0;
        self.lanes[lane] = Some(s);
    }

    /// Work is waiting for a lane (preemption pays off).
    fn has_waiters(&self) -> bool {
        !self.ready.is_empty() || !self.queue.is_empty()
    }

    /// Swap a lane's session out: save its state, stamp the image CRC,
    /// and park it on the ready queue.  The fault plan may flip a bit of
    /// the image *after* stamping (bit-rot in the swapped-out copy) --
    /// `resume` must catch that at check-in.
    fn preempt(&mut self, lane: usize) -> Result<()> {
        let mut s = self.lanes[lane]
            .take()
            .ok_or(EngineError::EmptyLane { lane, op: "preempt" })?;
        let mut st = s.state.take().unwrap_or_else(|| self.arena.take());
        self.dec.save_lane(lane, &mut st)?;
        self.swaps += 1;
        self.swap_bytes += st.size_bytes() as u64;
        s.state_crc = lane_state_crc(&st);
        if let Some(ServeFault::CorruptState { byte, .. }) =
            self.cfg.fault.take_corrupt_state(s.req.id)
        {
            if corrupt_lane_state(&mut st, byte) {
                self.corruptions_injected += 1;
                if self.cfg.trace.on() {
                    self.cfg.trace.instant(
                        Self::req_track(s.req.id),
                        "fault",
                        "fault.corrupt_state",
                        self.tick,
                        vec![("byte".to_string(), Json::from(byte))],
                    );
                }
            }
        }
        s.state = Some(st);
        s.preemptions += 1;
        if self.cfg.trace.on() {
            self.cfg.trace.instant(
                Self::req_track(s.req.id),
                "serve",
                "req.preempt",
                self.tick,
                vec![("lane".to_string(), Json::from(lane))],
            );
        }
        self.ready.push_back(s);
        Ok(())
    }

    /// One engine tick over currently admitted lanes: batch step, absorb
    /// logits, retire finished lanes, preempt expired quanta.  Returns
    /// without advancing any lane when the decoder fails -- the caller
    /// decides whether the error is an injected fault to absorb.
    fn step_batch(&mut self) -> Result<()> {
        let b = self.lanes.len();
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = 0u64;
        for (l, slot) in self.lanes.iter().enumerate() {
            if let Some(s) = slot {
                toks[l] = s.next_input()?;
                pos[l] = s.pos;
                active += 1;
            }
        }
        let logits = self.dec.decode_step(&Tensor::i32(&[b], toks), &pos)?;
        let v = *logits.shape.last().unwrap();
        let rows = logits.as_f32()?;
        self.steps += 1;
        self.active_lane_steps += active;
        let tick = self.tick;
        if self.cfg.trace.on() {
            // One span per decoder step that ran a batch; the `active`
            // arg makes occupancy re-derivable from the trace alone
            // (obs::span_occupancy == ServeReport::occupancy exactly).
            self.cfg.trace.span(
                Track::new("engine", 0),
                "serve",
                "engine.step",
                tick,
                1,
                vec![("active".to_string(), Json::from(active))],
            );
        }
        for lane in 0..b {
            let Some(s) = self.lanes[lane].as_mut() else { continue };
            let done = s.absorb(&rows[lane * v..(lane + 1) * v], tick);
            if done {
                self.retire(lane, Outcome::Finished)?;
            } else if let Some(q) = self.cfg.preempt_after {
                if self.lanes[lane].as_ref().is_some_and(|s| s.resident_steps >= q)
                    && self.has_waiters()
                {
                    self.preempt(lane)?;
                }
            }
        }
        self.tick += 1;
        Ok(())
    }

    /// Absorb an injected decode-step fault: no lane advanced, so the
    /// victim is rewound to its prompt and requeued (or retired `Failed`
    /// past the retry budget) while every other lane replays the same
    /// step next tick, untouched.  The tick is burned either way.
    fn on_step_fault(&mut self, lane: usize) {
        self.faults_injected += 1;
        if self.cfg.trace.on() {
            self.cfg.trace.instant(
                Track::new("engine", 0),
                "fault",
                "fault.step",
                self.tick,
                vec![("lane".to_string(), Json::from(lane))],
            );
        }
        if let Some(slot) = self.lanes.get_mut(lane) {
            if let Some(mut s) = slot.take() {
                if let Some(st) = s.state.take() {
                    self.arena.put(st);
                }
                if s.retries >= self.cfg.max_retries {
                    // budget spent: the tokens sampled so far are a prefix
                    // of the reference stream (the faulted step advanced
                    // nothing), so keep them in the Failed record
                    let retries = s.retries;
                    self.finish(s, Outcome::Failed { retries });
                } else {
                    s.rewind_for_replay();
                    self.ready.push_back(s);
                }
            }
        }
        self.tick += 1;
    }

    /// Drive a full arrival trace to completion and report.  Arrivals
    /// that bounce off the full queue retry at the door every tick
    /// (clients with backpressure), so every request is eventually served,
    /// shed, or expired.  Injected decoder faults are absorbed here; any
    /// other decoder error propagates as a real backend failure.
    pub fn run_trace(&mut self, trace: &[Arrival]) -> Result<ServeReport> {
        debug_assert!(trace.windows(2).all(|w| w[0].at_tick <= w[1].at_tick));
        let t0 = Instant::now();
        let mut next = 0usize;
        let mut door: VecDeque<Request> = VecDeque::new();
        loop {
            if self.tick >= self.cfg.max_ticks {
                return Err(EngineError::MaxTicks { max: self.cfg.max_ticks }.into());
            }
            while next < trace.len() && trace[next].at_tick <= self.tick {
                door.push_back(trace[next].req.clone());
                next += 1;
            }
            while let Some(r) = door.pop_front() {
                if let Err(r) = self.submit(r) {
                    door.push_front(r);
                    break;
                }
            }
            self.expire();
            self.admit()?;
            if self.lanes.iter().all(Option::is_none) {
                if next >= trace.len() && door.is_empty() && !self.has_waiters() {
                    break;
                }
                // idle gap in the arrival trace: fast-forward the clock.
                // (With the trace drained, work can still be parked at the
                // door -- e.g. the queue drained entirely by shedding --
                // so step one tick and let the door drain next pass.)
                if next < trace.len() {
                    self.tick = self.tick.max(trace[next].at_tick);
                } else {
                    self.tick += 1;
                }
                continue;
            }
            if let Err(e) = self.step_batch() {
                match e.downcast_ref::<ServeFaultError>() {
                    Some(&ServeFaultError::Step { lane }) => self.on_step_fault(lane),
                    Some(&ServeFaultError::Stall) => {
                        self.stalled_ticks += 1;
                        if self.cfg.trace.on() {
                            self.cfg.trace.instant(
                                Track::new("engine", 0),
                                "fault",
                                "fault.stall",
                                self.tick,
                                Vec::new(),
                            );
                        }
                        self.tick += 1;
                    }
                    None => return Err(e),
                }
            }
        }
        let tokens_out: u64 = self
            .results
            .iter()
            .filter(|r| r.outcome == Outcome::Finished)
            .map(|r| r.tokens.len() as u64)
            .sum();
        let mut results = std::mem::take(&mut self.results);
        results.sort_by_key(|r| r.id);
        let report = ServeReport {
            results,
            ticks: self.tick,
            steps: self.steps,
            active_lane_steps: self.active_lane_steps,
            tokens_out,
            wall_secs: t0.elapsed().as_secs_f64(),
            swaps: self.swaps,
            swap_bytes: self.swap_bytes,
            state_reallocs: self.arena.reallocs(),
            rejected: self.queue.rejected,
            outcomes: self.outcomes,
            faults_injected: self.faults_injected,
            stalled_ticks: self.stalled_ticks,
            crc_failures: self.crc_failures,
            corruptions_injected: self.corruptions_injected,
            degraded: false,
        };
        if let Some(t) = self.cfg.trace.tracer() {
            t.with_metrics(|m| crate::coordinator::obs::absorb_serve_report(m, &report));
        }
        Ok(report)
    }
}

/// Run one request alone on lane 0 -- the single-stream semantics the
/// batched engine must reproduce bitwise.  Lane 0 is reset first; other
/// lanes (if any) idle on pad tokens.  Deadlines are ignored: this is the
/// reference stream a served request's tokens are compared against.
pub fn run_one<D: Decoder>(dec: &mut D, req: &Request) -> Result<Vec<i32>> {
    anyhow::ensure!(!req.prompt.is_empty() && req.max_new >= 1, "empty request");
    let b = dec.lanes();
    dec.reset_lane(0)?;
    let mut s = Session::new(req.clone(), 0);
    loop {
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        toks[0] = s.next_input()?;
        pos[0] = s.pos;
        let logits = dec.decode_step(&Tensor::i32(&[b], toks), &pos)?;
        let v = *logits.shape.last().unwrap();
        if s.absorb(&logits.as_f32()?[..v], 0) {
            return Ok(s.generated);
        }
    }
}
