//! Continuous-batching decode engine.
//!
//! A fixed-width batch of decode lanes is backed by a pool of per-request
//! sessions.  Each tick the engine ingests arrivals into the bounded
//! queue (backpressure), admits sessions into idle lanes (preempted
//! sessions resume first, FIFO), runs one `Decoder` step for the whole
//! batch, and retires or preempts lanes.  Prefill runs prompt tokens
//! through the same step loop before a lane goes live; admission of a
//! fresh request is a zero-copy lane reset, and state swaps go through
//! the `StateArena` free-list so steady state allocates nothing.
//!
//! Because per-lane computation is lane-independent (the `Decoder`
//! contract), every request's token stream is bitwise identical to
//! running it alone single-stream (`run_one`), whatever the interleaving.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::inference::Decoder;
use crate::tensor::Tensor;

use super::queue::{Arrival, BoundedQueue, Request};
use super::session::{Session, StateArena};

#[derive(Clone, Debug)]
pub struct EngineCfg {
    /// queue depth before submissions bounce (backpressure)
    pub max_pending: usize,
    /// decode-step quantum after which a lane is swapped out for waiting
    /// work (None = run every request to completion)
    pub preempt_after: Option<u64>,
    /// safety stop for runaway traces
    pub max_ticks: u64,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg { max_pending: 1024, preempt_after: None, max_ticks: 10_000_000 }
    }
}

/// Final per-request record (ticks are engine steps, deterministic).
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrival_tick: u64,
    pub admit_tick: u64,
    pub first_token_tick: u64,
    pub finish_tick: u64,
    pub preemptions: u32,
}

impl RequestResult {
    /// Ticks spent queued before first entering a lane.
    pub fn queue_wait(&self) -> u64 {
        self.admit_tick - self.arrival_tick
    }

    /// Time-to-first-token in ticks from arrival.
    pub fn ttft(&self) -> u64 {
        self.first_token_tick - self.arrival_tick
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub results: Vec<RequestResult>,
    /// engine clock at the end of the trace
    pub ticks: u64,
    /// decoder step invocations (== ticks that ran a batch)
    pub steps: u64,
    /// sum over steps of the number of live lanes
    pub active_lane_steps: u64,
    pub tokens_out: u64,
    pub wall_secs: f64,
    /// state check-ins/outs (preemption swaps; fresh admits are resets)
    pub swaps: u64,
    pub swap_bytes: u64,
    /// LaneState buffer (re)allocations across the whole run
    pub state_reallocs: u64,
    /// bounced submit attempts (backpressure)
    pub rejected: u64,
}

impl ServeReport {
    /// Mean live lanes per decoder step (> 1 means batching is paying).
    pub fn occupancy(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.active_lane_steps as f64 / self.steps as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.wall_secs
    }
}

pub struct Engine<D: Decoder> {
    pub dec: D,
    cfg: EngineCfg,
    queue: BoundedQueue<Session>,
    /// preempted sessions waiting to resume; served before fresh admits
    ready: VecDeque<Session>,
    lanes: Vec<Option<Session>>,
    arena: StateArena,
    tick: u64,
    steps: u64,
    active_lane_steps: u64,
    swaps: u64,
    swap_bytes: u64,
    results: Vec<RequestResult>,
}

impl<D: Decoder> Engine<D> {
    pub fn new(dec: D, cfg: EngineCfg) -> Self {
        let lanes = (0..dec.lanes()).map(|_| None).collect();
        let queue = BoundedQueue::new(cfg.max_pending);
        Engine {
            dec,
            cfg,
            queue,
            ready: VecDeque::new(),
            lanes,
            arena: StateArena::default(),
            tick: 0,
            steps: 0,
            active_lane_steps: 0,
            swaps: 0,
            swap_bytes: 0,
            results: Vec::new(),
        }
    }

    /// Submit one request at the current tick; `Err` = backpressure.
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        debug_assert!(!req.prompt.is_empty() && req.max_new >= 1);
        self.queue
            .submit(Session::new(req, self.tick))
            .map_err(|s| s.req)
    }

    /// Fill idle lanes: resume preempted sessions first (FIFO), then admit
    /// fresh requests with a zero-copy lane reset.
    fn admit(&mut self) -> Result<()> {
        for lane in 0..self.lanes.len() {
            if self.lanes[lane].is_some() {
                continue;
            }
            let mut s = if let Some(mut s) = self.ready.pop_front() {
                let st = s.state.take().expect("preempted session must carry state");
                self.dec.load_lane(lane, &st)?;
                self.swaps += 1;
                self.swap_bytes += st.size_bytes() as u64;
                self.arena.put(st);
                s
            } else if let Some(s) = self.queue.pop() {
                self.dec.reset_lane(lane)?;
                s
            } else {
                break;
            };
            if s.admit_tick.is_none() {
                s.admit_tick = Some(self.tick);
            }
            s.resident_steps = 0;
            self.lanes[lane] = Some(s);
        }
        Ok(())
    }

    /// Work is waiting for a lane (preemption pays off).
    fn has_waiters(&self) -> bool {
        !self.ready.is_empty() || !self.queue.is_empty()
    }

    fn retire(&mut self, lane: usize) {
        let s = self.lanes[lane].take().expect("retire on empty lane");
        if let Some(st) = s.state {
            self.arena.put(st);
        }
        self.results.push(RequestResult {
            id: s.req.id,
            tokens: s.generated,
            arrival_tick: s.arrival_tick,
            admit_tick: s.admit_tick.expect("retired session was admitted"),
            first_token_tick: s.first_token_tick.expect("retired session sampled"),
            finish_tick: s.finish_tick.expect("retired session finished"),
            preemptions: s.preemptions,
        });
    }

    fn preempt(&mut self, lane: usize) -> Result<()> {
        let mut s = self.lanes[lane].take().expect("preempt on empty lane");
        let mut st = s.state.take().unwrap_or_else(|| self.arena.take());
        self.dec.save_lane(lane, &mut st)?;
        self.swaps += 1;
        self.swap_bytes += st.size_bytes() as u64;
        s.state = Some(st);
        s.preemptions += 1;
        self.ready.push_back(s);
        self.lanes[lane] = None;
        Ok(())
    }

    /// One engine tick over currently admitted lanes: batch step, absorb
    /// logits, retire finished lanes, preempt expired quanta.
    fn step_batch(&mut self) -> Result<()> {
        let b = self.lanes.len();
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = 0u64;
        for (l, slot) in self.lanes.iter().enumerate() {
            if let Some(s) = slot {
                toks[l] = s.next_input();
                pos[l] = s.pos;
                active += 1;
            }
        }
        let logits = self.dec.decode_step(&Tensor::i32(&[b], toks), &pos)?;
        let v = *logits.shape.last().unwrap();
        let rows = logits.as_f32()?;
        self.steps += 1;
        self.active_lane_steps += active;
        let tick = self.tick;
        for lane in 0..b {
            let Some(s) = self.lanes[lane].as_mut() else { continue };
            let done = s.absorb(&rows[lane * v..(lane + 1) * v], tick);
            if done {
                self.retire(lane);
            } else if let Some(q) = self.cfg.preempt_after {
                if self.lanes[lane].as_ref().is_some_and(|s| s.resident_steps >= q)
                    && self.has_waiters()
                {
                    self.preempt(lane)?;
                }
            }
        }
        self.tick += 1;
        Ok(())
    }

    /// Drive a full arrival trace to completion and report.  Arrivals
    /// that bounce off the full queue retry at the door every tick
    /// (clients with backpressure), so every request is eventually served.
    pub fn run_trace(&mut self, trace: &[Arrival]) -> Result<ServeReport> {
        debug_assert!(trace.windows(2).all(|w| w[0].at_tick <= w[1].at_tick));
        let t0 = Instant::now();
        let mut next = 0usize;
        let mut door: VecDeque<Request> = VecDeque::new();
        loop {
            anyhow::ensure!(
                self.tick < self.cfg.max_ticks,
                "engine exceeded max_ticks ({})",
                self.cfg.max_ticks
            );
            while next < trace.len() && trace[next].at_tick <= self.tick {
                door.push_back(trace[next].req.clone());
                next += 1;
            }
            while let Some(r) = door.pop_front() {
                if let Err(r) = self.submit(r) {
                    door.push_front(r);
                    break;
                }
            }
            self.admit()?;
            if self.lanes.iter().all(Option::is_none) {
                if next >= trace.len() && door.is_empty() && !self.has_waiters() {
                    break;
                }
                // idle gap in the arrival trace: fast-forward the clock
                self.tick = self.tick.max(trace[next].at_tick);
                continue;
            }
            self.step_batch()?;
        }
        let tokens_out: u64 = self.results.iter().map(|r| r.tokens.len() as u64).sum();
        let mut results = std::mem::take(&mut self.results);
        results.sort_by_key(|r| r.id);
        Ok(ServeReport {
            results,
            ticks: self.tick,
            steps: self.steps,
            active_lane_steps: self.active_lane_steps,
            tokens_out,
            wall_secs: t0.elapsed().as_secs_f64(),
            swaps: self.swaps,
            swap_bytes: self.swap_bytes,
            state_reallocs: self.arena.reallocs(),
            rejected: self.queue.rejected,
        })
    }
}

/// Run one request alone on lane 0 -- the single-stream semantics the
/// batched engine must reproduce bitwise.  Lane 0 is reset first; other
/// lanes (if any) idle on pad tokens.
pub fn run_one<D: Decoder>(dec: &mut D, req: &Request) -> Result<Vec<i32>> {
    anyhow::ensure!(!req.prompt.is_empty() && req.max_new >= 1, "empty request");
    let b = dec.lanes();
    dec.reset_lane(0)?;
    let mut s = Session::new(req.clone(), 0);
    loop {
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        toks[0] = s.next_input();
        pos[0] = s.pos;
        let logits = dec.decode_step(&Tensor::i32(&[b], toks), &pos)?;
        let v = *logits.shape.last().unwrap();
        if s.absorb(&logits.as_f32()?[..v], 0) {
            return Ok(s.generated);
        }
    }
}
