//! Request queue: bounded FIFO admission with backpressure, plus a
//! deterministic Poisson-ish arrival-trace generator for benches and the
//! CLI (exponential inter-arrival gaps via inverse-CDF on the seeded Rng,
//! rounded to integer engine ticks).

use std::collections::VecDeque;

use crate::rng::Rng;

use super::sampler::Sampling;

/// One decode request.  `seed` drives the request's private sampler RNG,
/// so its token stream is independent of lane/batch scheduling.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub eos: Option<i32>,
    pub sampling: Sampling,
    pub seed: u64,
    /// Deadline as a TTL in engine ticks from arrival: the request must
    /// finish by `arrival_tick + ttl` or it is expired (running/queued)
    /// or shed at admission (when it provably cannot finish in time).
    /// `None` = no deadline.
    pub ttl: Option<u64>,
}

impl Request {
    /// Worst-case decode steps to completion from a cold start: every
    /// prompt token but the last is a prefill step, then up to `max_new`
    /// sampling steps (EOS may finish earlier; admission control is
    /// deliberately conservative and budgets the worst case).
    pub fn min_service_steps(&self) -> u64 {
        (self.prompt.len().saturating_sub(1) + self.max_new) as u64
    }
}

/// A request plus the engine tick it arrives at.
#[derive(Clone, Debug)]
pub struct Arrival {
    pub at_tick: u64,
    pub req: Request,
}

/// Deterministic Poisson-ish arrival trace: n requests whose inter-arrival
/// gaps are exponential with mean `mean_gap` ticks.  `make(id)` builds the
/// request body.
pub fn poisson_trace(
    rng: &mut Rng,
    n: usize,
    mean_gap: f64,
    mut make: impl FnMut(u64) -> Request,
) -> Vec<Arrival> {
    let mut t = 0u64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // u in [0, 1) so 1-u in (0, 1]: ln is finite, gap >= 0
        let u = rng.f32() as f64;
        t += (-mean_gap * (1.0 - u).ln()).round() as u64;
        out.push(Arrival { at_tick: t, req: make(i as u64) });
    }
    out
}

/// Bounded FIFO: `submit` refuses (backpressure) once `max_pending` items
/// are queued, and counts the bounced attempts.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    items: VecDeque<T>,
    pub max_pending: usize,
    pub submitted: u64,
    pub rejected: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(max_pending: usize) -> Self {
        assert!(max_pending >= 1, "queue depth must be >= 1");
        BoundedQueue {
            items: VecDeque::new(),
            max_pending,
            submitted: 0,
            rejected: 0,
        }
    }

    /// Enqueue, or hand the item back when full (caller retries later).
    pub fn submit(&mut self, item: T) -> Result<(), T> {
        if self.items.len() >= self.max_pending {
            self.rejected += 1;
            return Err(item);
        }
        self.submitted += 1;
        self.items.push_back(item);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    /// Remove and return every queued item matching `pred`, preserving
    /// FIFO order of the rest (deadline-expiry scans).
    pub fn extract(&mut self, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut out = Vec::new();
        let mut kept = VecDeque::with_capacity(self.items.len());
        for item in self.items.drain(..) {
            if pred(&item) {
                out.push(item);
            } else {
                kept.push_back(item);
            }
        }
        self.items = kept;
        out
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: vec![1],
            max_new: 1,
            eos: None,
            sampling: Sampling::Greedy,
            seed: id,
            ttl: None,
        }
    }

    #[test]
    fn extract_removes_matches_keeps_fifo() {
        let mut q = BoundedQueue::new(8);
        for id in 0..5 {
            q.submit(req(id)).unwrap();
        }
        let out = q.extract(|r| r.id % 2 == 1);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 4);
        assert!(q.extract(|_| true).is_empty());
    }

    #[test]
    fn min_service_steps_budget() {
        let mut r = req(0);
        r.prompt = vec![1, 2, 3]; // 2 prefill steps
        r.max_new = 4;
        assert_eq!(r.min_service_steps(), 6);
        r.prompt = vec![1];
        assert_eq!(r.min_service_steps(), 4);
    }

    #[test]
    fn fifo_order_and_backpressure() {
        let mut q = BoundedQueue::new(2);
        assert!(q.submit(req(0)).is_ok());
        assert!(q.submit(req(1)).is_ok());
        let bounced = q.submit(req(2));
        assert!(bounced.is_err(), "third submit must bounce at depth 2");
        assert_eq!(q.rejected, 1);
        assert_eq!(q.pop().unwrap().id, 0);
        assert!(q.submit(bounced.unwrap_err()).is_ok());
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
        assert_eq!(q.submitted, 3);
    }

    #[test]
    fn poisson_trace_is_deterministic_and_ordered() {
        let a = poisson_trace(&mut Rng::new(11), 64, 3.0, req);
        let b = poisson_trace(&mut Rng::new(11), 64, 3.0, req);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_tick, y.at_tick);
            assert_eq!(x.req.id, y.req.id);
        }
        assert!(a.windows(2).all(|w| w[0].at_tick <= w[1].at_tick));
        // mean gap in the right ballpark (exponential, n=64)
        let total = a.last().unwrap().at_tick as f64;
        let mean = total / 63.0;
        assert!(mean > 0.5 && mean < 9.0, "mean inter-arrival {mean}");
    }
}
