//! Continuous-batching serving engine (the serving form of paper Fig. 5).
//!
//! Linear-MoE layers carry one constant-size recurrent state per head, so
//! a decode lane's state can be checked in/out between steps for the cost
//! of an O(1) memcpy -- the LSM analogue of paged KV, trivially cheap
//! because state does not grow with position.  This module turns the
//! `Decoder` step functions (`crate::inference`) into a serving engine:
//!
//!  - `queue`:   requests, bounded FIFO admission (backpressure), and a
//!               deterministic Poisson-ish arrival-trace generator
//!  - `session`: per-request lifecycle (prefill -> live -> finished),
//!               sampler state, tick-based metrics, and the `StateArena`
//!               free-list that makes steady-state admission alloc-free
//!  - `sampler`: seeded greedy / temperature / top-k sampling
//!  - `engine`:  the fixed-width decode batch whose lanes are backed by a
//!               pool of sessions; admission, prefill through the same
//!               step loop, round-robin preemption, termination, metrics
//!  - `refmodel`: artifact-free reference backends (constant-state LSM vs
//!               KV-staircase attention) for tests, benches, and the CLI
//!  - `fault`:   deterministic serving fault injection (decoder step
//!               errors, lane-state bit-rot, backend stalls) plus the
//!               CRC-32 integrity layer on lane-state images
//!
//! Per-lane computation is lane-independent, so the engine is
//! semantics-preserving: each request's token stream is bitwise identical
//! to running it alone single-stream (`tests/serve.rs` pins this down).
//! The engine supervises faults without giving that up: non-victim lanes
//! stay bitwise identical, victims recover by deterministic replay or
//! retire with typed outcomes, and requests carry deadlines the scheduler
//! enforces by expiry and admission-time shedding (`tests/serve_faults.rs`).

pub mod engine;
pub mod fault;
pub mod queue;
pub mod refmodel;
pub mod sampler;
pub mod session;

pub use engine::{run_one, Engine, EngineCfg, EngineError, Outcome, RequestResult,
                 ServeReport};
pub use fault::{corrupt_lane_state, lane_state_crc, FaultDecoder, ServeFault,
                ServeFaultError, ServeFaultPlan};
pub use queue::{poisson_trace, Arrival, BoundedQueue, Request};
pub use refmodel::{RefAttnDecoder, RefLsmDecoder};
pub use sampler::{Sampler, Sampling};
pub use session::{Session, StateArena};
