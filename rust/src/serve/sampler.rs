//! Seeded token sampling for the serving engine.
//!
//! Each request carries its own `Sampling` config and RNG seed, so a
//! request's token stream is deterministic no matter which lane or batch
//! it is scheduled into.  Greedy paths consume no randomness; ties break
//! to the first (lowest) index, matching `inference::greedy`.

use crate::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    /// Argmax; ties break to the lowest index.
    Greedy,
    /// Softmax over logits / temp.  `temp < 1e-6` degrades to greedy.
    Temperature { temp: f32 },
    /// Restrict to the k best logits (stable by value desc, index asc),
    /// then temperature-sample among them.
    TopK { k: usize, temp: f32 },
}

pub struct Sampler {
    pub cfg: Sampling,
    rng: Rng,
}

/// Argmax with first-index tie-breaking (the documented greedy contract).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Inverse-CDF draw from softmax(vals / temp) at uniform `u` in [0, 1).
/// Subtracting the max first means temp -> 0 concentrates all mass on the
/// argmax, so tiny temperatures converge to greedy on distinct logits.
fn pick_softmax(vals: &[f32], temp: f32, u: f32) -> usize {
    let m = vals.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let w: Vec<f64> = vals
        .iter()
        .map(|&x| (((x - m) / temp) as f64).exp())
        .collect();
    let z: f64 = w.iter().sum();
    let target = u as f64 * z;
    let mut acc = 0.0;
    for (i, wi) in w.iter().enumerate() {
        acc += wi;
        if target < acc {
            return i;
        }
    }
    vals.len() - 1
}

impl Sampler {
    pub fn new(cfg: Sampling, seed: u64) -> Self {
        Sampler { cfg, rng: Rng::new(seed) }
    }

    /// Pick the next token from one (V,) row of logits.
    pub fn next(&mut self, logits: &[f32]) -> usize {
        match self.cfg {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature { temp } => {
                if temp < 1e-6 {
                    return argmax(logits);
                }
                let u = self.rng.f32();
                pick_softmax(logits, temp, u)
            }
            Sampling::TopK { k, temp } => {
                let k = k.clamp(1, logits.len());
                if temp < 1e-6 {
                    return argmax(logits);
                }
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| {
                    logits[b]
                        .partial_cmp(&logits[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                idx.truncate(k);
                let vals: Vec<f32> = idx.iter().map(|&i| logits[i]).collect();
                let u = self.rng.f32();
                idx[pick_softmax(&vals, temp, u)]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng;

    fn rand_logits(r: &mut Rng, v: usize) -> Vec<f32> {
        (0..v).map(|_| r.normal()).collect()
    }

    #[test]
    fn same_seed_same_token_stream() {
        let mut gen = Rng::new(7);
        let rows: Vec<Vec<f32>> = (0..50).map(|_| rand_logits(&mut gen, 32)).collect();
        for cfg in [
            Sampling::Greedy,
            Sampling::Temperature { temp: 0.8 },
            Sampling::TopK { k: 5, temp: 1.1 },
        ] {
            let mut a = Sampler::new(cfg, 42);
            let mut b = Sampler::new(cfg, 42);
            for row in &rows {
                assert_eq!(a.next(row), b.next(row), "{cfg:?} diverged");
            }
        }
    }

    #[test]
    fn different_seeds_differ_somewhere() {
        let mut gen = Rng::new(8);
        let rows: Vec<Vec<f32>> = (0..100).map(|_| rand_logits(&mut gen, 32)).collect();
        let mut a = Sampler::new(Sampling::Temperature { temp: 1.0 }, 1);
        let mut b = Sampler::new(Sampling::Temperature { temp: 1.0 }, 2);
        assert!(
            rows.iter().any(|r| a.next(r) != b.next(r)),
            "independent seeds should not produce identical streams"
        );
    }

    #[test]
    fn top_k_never_leaves_the_k_best() {
        rng::check("topk_membership", 20, |r| {
            let v = 16 + r.below(32);
            let k = 1 + r.below(6);
            let logits = rand_logits(r, v);
            // the k best values by the sampler's own stable order
            let mut idx: Vec<usize> = (0..v).collect();
            idx.sort_by(|&a, &b| {
                logits[b]
                    .partial_cmp(&logits[a])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            let best: std::collections::HashSet<usize> =
                idx[..k].iter().copied().collect();
            let mut s = Sampler::new(
                Sampling::TopK { k, temp: 1.3 },
                r.next_u64(),
            );
            for _ in 0..200 {
                let t = s.next(&logits);
                assert!(best.contains(&t), "sampled {t} outside top-{k}");
            }
        });
    }

    #[test]
    fn tiny_temperature_converges_to_greedy() {
        let mut gen = Rng::new(9);
        let mut checked = 0;
        for _ in 0..80 {
            let logits = rand_logits(&mut gen, 24);
            let g = argmax(&logits);
            // only claim convergence where the argmax is separated: at
            // temp 1e-5 a 0.1 logit gap puts every non-max weight at
            // exp(-10000), which underflows to exactly 0.0
            let runner_up = logits
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != g)
                .map(|(_, &x)| x)
                .fold(f32::NEG_INFINITY, f32::max);
            if logits[g] - runner_up < 0.1 {
                continue;
            }
            checked += 1;
            let mut t = Sampler::new(Sampling::Temperature { temp: 1e-5 }, 5);
            let mut k = Sampler::new(Sampling::TopK { k: 4, temp: 1e-5 }, 5);
            assert_eq!(t.next(&logits), g, "temperature -> 0 must match greedy");
            assert_eq!(k.next(&logits), g, "top-k with temp -> 0 must match greedy");
            // and the hard cutoff below 1e-6 is exactly greedy
            let mut z = Sampler::new(Sampling::Temperature { temp: 0.0 }, 5);
            assert_eq!(z.next(&logits), g);
        }
        assert!(checked > 10, "too few separated rows ({checked})");
    }

    #[test]
    fn greedy_ties_break_to_first_index() {
        let logits = vec![1.0, 5.0, 5.0, -2.0];
        assert_eq!(argmax(&logits), 1);
        let mut s = Sampler::new(Sampling::Greedy, 0);
        assert_eq!(s.next(&logits), 1);
        // matches the batched inference::greedy kernel on the same row
        let t = crate::tensor::Tensor::f32(&[1, 4], logits);
        let g = crate::inference::greedy(&t).unwrap();
        assert_eq!(g.as_i32().unwrap(), &[1]);
    }
}
