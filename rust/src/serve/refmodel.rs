//! Artifact-free reference decode backends for the serving engine.
//!
//! `RefLsmDecoder` is a constant-state linear recurrence (the Linear-MoE
//! serving regime: O(1) state per lane, flat per-token cost).
//! `RefAttnDecoder` is its attention counterpart: per-lane KV history kept
//! in a power-of-two staircase, so state bytes and per-token cost grow
//! with position -- the Fig. 5 contrast, in serving form.
//!
//! Per-lane math is strictly lane-independent and sequentially evaluated
//! in a fixed order, so a lane's token stream is bitwise identical no
//! matter which batch it rides in; `tests/serve.rs` pins this down by
//! replaying every request single-stream.

use anyhow::Result;

use crate::inference::{Decoder, LaneState};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Constant-state reference LSM: per lane, `s = s * decay + emb[token]`,
/// logits = s . Wout.  Position-invariant, like the real kernels.
pub struct RefLsmDecoder {
    lanes: usize,
    pub vocab: usize,
    pub d: usize,
    emb: Vec<f32>,   // vocab * d
    wout: Vec<f32>,  // d * vocab
    decay: Vec<f32>, // d
    state: Vec<f32>, // lanes * d
}

impl RefLsmDecoder {
    pub fn new(lanes: usize, vocab: usize, d: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let emb = (0..vocab * d).map(|_| rng.normal() * 0.5).collect();
        let wout = (0..d * vocab).map(|_| rng.normal() * 0.3).collect();
        let decay = (0..d).map(|_| 0.5 + 0.45 * rng.f32()).collect();
        RefLsmDecoder {
            lanes,
            vocab,
            d,
            emb,
            wout,
            decay,
            state: vec![0.0; lanes * d],
        }
    }
}

impl Decoder for RefLsmDecoder {
    fn lanes(&self) -> usize {
        self.lanes
    }

    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor> {
        let t = tokens.as_i32()?;
        anyhow::ensure!(
            t.len() == self.lanes && pos.len() == self.lanes,
            "token/pos width != lanes"
        );
        let (d, v) = (self.d, self.vocab);
        let mut logits = vec![0f32; self.lanes * v];
        for l in 0..self.lanes {
            let tok = (t[l].max(0) as usize).min(v - 1);
            let s = &mut self.state[l * d..(l + 1) * d];
            for j in 0..d {
                s[j] = s[j] * self.decay[j] + self.emb[tok * d + j];
            }
            let row = &mut logits[l * v..(l + 1) * v];
            for j in 0..d {
                let sj = s[j];
                for (x, w) in row.iter_mut().zip(&self.wout[j * v..(j + 1) * v]) {
                    *x += sj * w;
                }
            }
        }
        Ok(Tensor::f32(&[self.lanes, v], logits))
    }

    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane out of range");
        let d = self.d;
        let t = out.slot(0, &[d], true);
        t.as_f32_mut()?
            .copy_from_slice(&self.state[lane * d..(lane + 1) * d]);
        out.tensors.truncate(1);
        Ok(())
    }

    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane out of range");
        anyhow::ensure!(
            src.tensors.len() == 1 && src.tensors[0].shape == [self.d],
            "lane state does not fit RefLsmDecoder"
        );
        let d = self.d;
        self.state[lane * d..(lane + 1) * d]
            .copy_from_slice(src.tensors[0].as_f32()?);
        Ok(())
    }

    fn reset_lane(&mut self, lane: usize) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane out of range");
        let d = self.d;
        self.state[lane * d..(lane + 1) * d].fill(0.0);
        Ok(())
    }

    fn lane_state_bytes(&self, _pos: usize) -> usize {
        self.d * 4
    }
}

struct LaneKv {
    /// staircase-padded to `cap * d`; `len` rows are live
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

/// KV-staircase reference attention: each lane appends one (k, v) row per
/// step and attends over its whole history, padded to the next power of
/// two >= len (min `min_cap`), so swap bytes and per-token cost climb
/// with position.
pub struct RefAttnDecoder {
    lanes: usize,
    pub vocab: usize,
    pub d: usize,
    pub min_cap: usize,
    emb_k: Vec<f32>, // vocab * d
    emb_v: Vec<f32>, // vocab * d
    emb_q: Vec<f32>, // vocab * d
    wout: Vec<f32>,  // d * vocab
    kv: Vec<LaneKv>,
}

fn staircase(len: usize, min_cap: usize) -> usize {
    len.max(1).next_power_of_two().max(min_cap)
}

impl RefAttnDecoder {
    pub fn new(lanes: usize, vocab: usize, d: usize, min_cap: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut mat = |scale: f32, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() * scale).collect()
        };
        let emb_k = mat(0.4, vocab * d);
        let emb_v = mat(0.4, vocab * d);
        let emb_q = mat(0.4, vocab * d);
        let wout = mat(0.3, d * vocab);
        let kv = (0..lanes)
            .map(|_| LaneKv {
                k: vec![0.0; min_cap * d],
                v: vec![0.0; min_cap * d],
                len: 0,
            })
            .collect();
        RefAttnDecoder { lanes, vocab, d, min_cap, emb_k, emb_v, emb_q, wout, kv }
    }
}

impl Decoder for RefAttnDecoder {
    fn lanes(&self) -> usize {
        self.lanes
    }

    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor> {
        let t = tokens.as_i32()?;
        anyhow::ensure!(
            t.len() == self.lanes && pos.len() == self.lanes,
            "token/pos width != lanes"
        );
        let (d, v) = (self.d, self.vocab);
        let mut logits = vec![0f32; self.lanes * v];
        for l in 0..self.lanes {
            let tok = (t[l].max(0) as usize).min(v - 1);
            let lane = &mut self.kv[l];
            // append this step's (k, v), growing the staircase if full
            let cap = staircase(lane.len + 1, self.min_cap);
            if cap * d > lane.k.len() {
                lane.k.resize(cap * d, 0.0);
                lane.v.resize(cap * d, 0.0);
            }
            lane.k[lane.len * d..(lane.len + 1) * d]
                .copy_from_slice(&self.emb_k[tok * d..(tok + 1) * d]);
            lane.v[lane.len * d..(lane.len + 1) * d]
                .copy_from_slice(&self.emb_v[tok * d..(tok + 1) * d]);
            lane.len += 1;
            // softmax attention over the lane's history
            let q = &self.emb_q[tok * d..(tok + 1) * d];
            let scores: Vec<f32> = (0..lane.len)
                .map(|r| {
                    let kr = &lane.k[r * d..(r + 1) * d];
                    q.iter().zip(kr).map(|(a, b)| a * b).sum::<f32>()
                        / (d as f32).sqrt()
                })
                .collect();
            let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let w: Vec<f32> = scores.iter().map(|&s| (s - m).exp()).collect();
            let z: f32 = w.iter().sum();
            let mut ctx = vec![0f32; d];
            for (r, wi) in w.iter().enumerate() {
                let vr = &lane.v[r * d..(r + 1) * d];
                for (c, x) in ctx.iter_mut().zip(vr) {
                    *c += wi / z * x;
                }
            }
            let row = &mut logits[l * v..(l + 1) * v];
            for j in 0..d {
                let cj = ctx[j];
                for (x, wo) in row.iter_mut().zip(&self.wout[j * v..(j + 1) * v]) {
                    *x += cj * wo;
                }
            }
        }
        Ok(Tensor::f32(&[self.lanes, v], logits))
    }

    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane out of range");
        let d = self.d;
        let kv = &self.kv[lane];
        let cap = kv.k.len() / d;
        out.slot(0, &[cap, d], true)
            .as_f32_mut()?
            .copy_from_slice(&kv.k);
        out.slot(1, &[cap, d], true)
            .as_f32_mut()?
            .copy_from_slice(&kv.v);
        out.slot(2, &[1], false).as_i32_mut()?[0] = kv.len as i32;
        out.tensors.truncate(3);
        Ok(())
    }

    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane out of range");
        anyhow::ensure!(
            src.tensors.len() == 3
                && src.tensors[0].shape.len() == 2
                && src.tensors[0].shape[1] == self.d
                && src.tensors[0].shape == src.tensors[1].shape,
            "lane state does not fit RefAttnDecoder"
        );
        let kv = &mut self.kv[lane];
        kv.k.clear();
        kv.k.extend_from_slice(src.tensors[0].as_f32()?);
        kv.v.clear();
        kv.v.extend_from_slice(src.tensors[1].as_f32()?);
        kv.len = src.tensors[2].as_i32()?[0] as usize;
        anyhow::ensure!(kv.len * self.d <= kv.k.len(), "saved len exceeds cap");
        Ok(())
    }

    fn reset_lane(&mut self, lane: usize) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane out of range");
        let d = self.d;
        let min = self.min_cap;
        let kv = &mut self.kv[lane];
        kv.len = 0;
        kv.k.clear();
        kv.k.resize(min * d, 0.0);
        kv.v.clear();
        kv.v.resize(min * d, 0.0);
        Ok(())
    }

    fn lane_state_bytes(&self, pos: usize) -> usize {
        (2 * staircase(pos, self.min_cap) * self.d + 1) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lsm_lane_independence_and_roundtrip() {
        let mut a = RefLsmDecoder::new(2, 16, 8, 3);
        let mut b = RefLsmDecoder::new(1, 16, 8, 3);
        // run lane 1 of `a` and lane 0 of `b` on the same token stream,
        // with junk on a's lane 0
        let toks = [3i32, 7, 1, 7, 2];
        let mut last_a = None;
        let mut last_b = None;
        for (p, &tk) in toks.iter().enumerate() {
            let la = a
                .decode_step(&Tensor::i32(&[2], vec![9, tk]), &[0, p as i32])
                .unwrap();
            let lb = b
                .decode_step(&Tensor::i32(&[1], vec![tk]), &[p as i32])
                .unwrap();
            last_a = Some(la.as_f32().unwrap()[16..32].to_vec());
            last_b = Some(lb.as_f32().unwrap().to_vec());
        }
        assert_eq!(last_a.unwrap(), last_b.unwrap(), "lane must be batch-invariant");
        // save/load roundtrip preserves the stream bitwise
        let mut st = LaneState::default();
        a.save_lane(1, &mut st).unwrap();
        a.reset_lane(1).unwrap();
        a.load_lane(1, &st).unwrap();
        let la = a
            .decode_step(&Tensor::i32(&[2], vec![0, 5]), &[0, 5])
            .unwrap();
        let lb = b.decode_step(&Tensor::i32(&[1], vec![5]), &[5]).unwrap();
        assert_eq!(la.as_f32().unwrap()[16..32], lb.as_f32().unwrap()[..]);
    }

    #[test]
    fn attn_state_staircase_grows_and_roundtrips() {
        let mut dec = RefAttnDecoder::new(1, 16, 4, 4, 5);
        assert_eq!(dec.lane_state_bytes(1), (2 * 4 * 4 + 1) * 4);
        assert!(dec.lane_state_bytes(1000) > dec.lane_state_bytes(10));
        let mut rows = Vec::new();
        for p in 0..10 {
            let l = dec
                .decode_step(&Tensor::i32(&[1], vec![(p % 7) as i32]), &[p])
                .unwrap();
            rows.push(l.as_f32().unwrap().to_vec());
        }
        let mut st = LaneState::default();
        dec.save_lane(0, &mut st).unwrap();
        // 10 tokens -> staircase cap 16
        assert_eq!(st.tensors[0].shape, vec![16, 4]);
        dec.reset_lane(0).unwrap();
        dec.load_lane(0, &st).unwrap();
        let l = dec.decode_step(&Tensor::i32(&[1], vec![3]), &[10]).unwrap();
        // replay the same 11-token stream on a fresh decoder
        let mut fresh = RefAttnDecoder::new(1, 16, 4, 4, 5);
        for p in 0..10 {
            fresh
                .decode_step(&Tensor::i32(&[1], vec![(p % 7) as i32]), &[p])
                .unwrap();
        }
        let lf = fresh.decode_step(&Tensor::i32(&[1], vec![3]), &[10]).unwrap();
        assert_eq!(l.as_f32().unwrap(), lf.as_f32().unwrap());
    }
}
