//! Per-request sessions and the constant-state session pool.
//!
//! A `Session` owns everything one request needs to ride a decode lane:
//! the prompt cursor, the sampled tokens, the seeded sampler, tick-based
//! metrics, and -- while preempted out of the batch -- its saved recurrent
//! state plus the CRC-32 stamped over that image when it was checked out
//! (verified before the image is ever loaded back into a lane).  Because
//! LSM state is O(1) per lane, checking a session in or out of a lane is
//! a constant-size memcpy regardless of position.
//!
//! Recovery is a *replay*: a session whose decoder step faulted or whose
//! saved state failed its CRC is rewound to the prompt
//! (`rewind_for_replay`) and re-prefilled.  Sampling is a pure function
//! of (request seed, logits sequence), so a replayed attempt regenerates
//! the exact same token stream -- recovered requests stay bitwise
//! identical to an undisturbed run, and a half-finished attempt is always
//! a prefix of the reference stream (never wrong tokens).
//!
//! `StateArena` is a free-list of `LaneState` buffers: finished sessions
//! recycle their buffers, so steady-state admission and preemption
//! allocate nothing when shapes repeat (zero-copy where shapes allow).

use anyhow::Result;

use crate::inference::LaneState;
use crate::json::Json;

use super::engine::EngineError;
use super::queue::Request;
use super::sampler::Sampler;

pub struct Session {
    pub req: Request,
    pub sampler: Sampler,
    /// next input position: prompt tokens consumed + generated fed back
    pub pos: i32,
    pub generated: Vec<i32>,
    /// saved recurrent state while not resident in a lane
    pub state: Option<LaneState>,
    /// CRC-32 of `state` stamped at check-out; verified at check-in
    pub state_crc: u32,
    /// absolute deadline tick (`arrival + ttl`), None = no deadline
    pub deadline: Option<u64>,
    pub arrival_tick: u64,
    pub admit_tick: Option<u64>,
    pub first_token_tick: Option<u64>,
    pub finish_tick: Option<u64>,
    pub preemptions: u32,
    /// re-prefill replays so far (decoder faults + corrupt-state recoveries)
    pub retries: u32,
    /// decode steps since the session last entered a lane (preempt quantum)
    pub resident_steps: u64,
}

impl Session {
    pub fn new(req: Request, arrival_tick: u64) -> Self {
        let sampler = Sampler::new(req.sampling, req.seed);
        let deadline = req.ttl.map(|t| arrival_tick.saturating_add(t));
        Session {
            req,
            sampler,
            pos: 0,
            generated: Vec::new(),
            state: None,
            state_crc: 0,
            deadline,
            arrival_tick,
            admit_tick: None,
            first_token_tick: None,
            finish_tick: None,
            preemptions: 0,
            retries: 0,
            resident_steps: 0,
        }
    }

    /// Token to feed at the current position: the prompt during prefill,
    /// afterwards the last sampled token.  A live session past prefill
    /// with no sampled token is an engine invariant violation, surfaced
    /// as a typed error instead of a process abort.
    pub fn next_input(&self) -> Result<i32> {
        let p = self.pos as usize;
        if p < self.req.prompt.len() {
            Ok(self.req.prompt[p])
        } else {
            self.generated
                .last()
                .copied()
                .ok_or_else(|| EngineError::NoSampledToken { id: self.req.id }.into())
        }
    }

    /// Still running prompt tokens through the step loop (the logits of
    /// the step about to run will be discarded)?
    pub fn in_prefill(&self) -> bool {
        (self.pos as usize) + 1 < self.req.prompt.len()
    }

    /// Rewind to a fresh prompt replay after a decoder fault or a
    /// corrupted state image: cursor, sampled tokens, sampler RNG, and
    /// state all reset; arrival/admission metrics and fault counters are
    /// kept.  Determinism of the sampler in the request seed makes the
    /// replayed stream bitwise identical to an undisturbed run.
    pub fn rewind_for_replay(&mut self) {
        self.sampler = Sampler::new(self.req.sampling, self.req.seed);
        self.pos = 0;
        self.generated.clear();
        self.state = None;
        self.state_crc = 0;
        self.resident_steps = 0;
        self.retries += 1;
    }

    /// Worst-case decode steps still needed to finish from the current
    /// cursor (prefill remainder + unsampled token budget; EOS may cut
    /// this short, the budget is deliberately conservative).
    pub fn min_remaining_steps(&self) -> u64 {
        let prefill = self.req.prompt.len().saturating_sub(1 + self.pos as usize);
        let decode = self.req.max_new.saturating_sub(self.generated.len());
        (prefill + decode) as u64
    }

    /// Lifecycle facts for this session's `req.lifecycle` trace span --
    /// all logical-tick / counter values, so the args are deterministic.
    /// Optional ticks are emitted only when set (shed requests have no
    /// admit tick, expired ones may have no first token).
    pub fn trace_args(&self) -> Vec<(String, Json)> {
        let mut args = vec![
            ("id".to_string(), Json::from(self.req.id)),
            ("tokens".to_string(), Json::from(self.generated.len())),
            ("preemptions".to_string(), Json::from(self.preemptions as u64)),
            ("retries".to_string(), Json::from(self.retries as u64)),
        ];
        if let Some(t) = self.admit_tick {
            args.push(("admit_tick".to_string(), Json::from(t)));
        }
        if let Some(t) = self.first_token_tick {
            args.push(("first_token_tick".to_string(), Json::from(t)));
        }
        if let Some(d) = self.deadline {
            args.push(("deadline".to_string(), Json::from(d)));
        }
        args
    }

    /// Consume the logits row produced by feeding position `pos`: advance
    /// the cursor, sample once past prefill, and report termination
    /// (max-token budget exhausted or EOS sampled).
    pub fn absorb(&mut self, logits_row: &[f32], tick: u64) -> bool {
        let sample_now = (self.pos as usize) + 1 >= self.req.prompt.len();
        self.pos += 1;
        self.resident_steps += 1;
        if !sample_now {
            return false;
        }
        let tok = self.sampler.next(logits_row) as i32;
        if self.first_token_tick.is_none() {
            self.first_token_tick = Some(tick);
        }
        self.generated.push(tok);
        let done =
            self.generated.len() >= self.req.max_new || self.req.eos == Some(tok);
        if done {
            self.finish_tick = Some(tick);
        }
        done
    }
}

/// Free-list of `LaneState` buffers (the session pool's allocator).
#[derive(Debug, Default)]
pub struct StateArena {
    free: Vec<LaneState>,
    pub takes: u64,
    /// takes that found no recycled buffer (cold starts)
    pub misses: u64,
}

impl StateArena {
    pub fn take(&mut self) -> LaneState {
        self.takes += 1;
        self.free.pop().unwrap_or_else(|| {
            self.misses += 1;
            LaneState::default()
        })
    }

    pub fn put(&mut self, s: LaneState) {
        self.free.push(s);
    }

    /// Total buffer (re)allocations across every state the arena has seen
    /// and still holds -- flat in steady state when shapes repeat.
    pub fn reallocs(&self) -> u64 {
        self.free.iter().map(|s| s.reallocs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sampler::Sampling;

    fn req(prompt: Vec<i32>, max_new: usize, eos: Option<i32>) -> Request {
        Request {
            id: 0,
            prompt,
            max_new,
            eos,
            sampling: Sampling::Greedy,
            seed: 1,
            ttl: None,
        }
    }

    #[test]
    fn prefill_then_decode_then_budget_stop() {
        // prompt [5, 6]; greedy over a 3-token vocab
        let mut s = Session::new(req(vec![5, 6], 2, None), 0);
        assert_eq!(s.next_input().unwrap(), 5);
        assert!(s.in_prefill());
        assert_eq!(s.min_remaining_steps(), 3);
        assert!(!s.absorb(&[0., 0., 1.], 10)); // prefill step: no sample
        assert_eq!(s.next_input().unwrap(), 6);
        assert!(!s.in_prefill());
        assert!(!s.absorb(&[0., 0., 1.], 11)); // last prompt token: samples 2
        assert_eq!(s.generated, vec![2]);
        assert_eq!(s.first_token_tick, Some(11));
        assert_eq!(s.min_remaining_steps(), 1);
        assert_eq!(s.next_input().unwrap(), 2);
        assert!(s.absorb(&[1., 0., 0.], 12)); // budget of 2 reached
        assert_eq!(s.generated, vec![2, 0]);
        assert_eq!(s.finish_tick, Some(12));
    }

    #[test]
    fn eos_terminates_early() {
        let mut s = Session::new(req(vec![1], 100, Some(2)), 0);
        assert!(s.absorb(&[0., 0., 1.], 5), "sampling EOS must finish");
        assert_eq!(s.generated, vec![2]);
    }

    #[test]
    fn past_prefill_without_sample_is_typed_error() {
        let mut s = Session::new(req(vec![7], 4, None), 0);
        s.pos = 1; // cursor past the prompt with nothing sampled
        let err = s.next_input().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<EngineError>(),
            Some(EngineError::NoSampledToken { id: 0 })
        ));
    }

    #[test]
    fn deadline_is_arrival_plus_ttl() {
        let mut r = req(vec![1, 2], 3, None);
        r.ttl = Some(10);
        let s = Session::new(r, 7);
        assert_eq!(s.deadline, Some(17));
        assert_eq!(Session::new(req(vec![1], 1, None), 7).deadline, None);
    }

    #[test]
    fn rewind_replays_identical_stream() {
        let mut r = req(vec![5, 6], 3, None);
        r.sampling = Sampling::TopK { k: 2, temp: 1.0 };
        let rows: Vec<Vec<f32>> = vec![
            vec![0.1, 0.9, 0.3],
            vec![0.7, 0.2, 0.4],
            vec![0.5, 0.5, 0.1],
            vec![0.9, 0.1, 0.2],
        ];
        let mut s = Session::new(r, 0);
        for row in &rows {
            if s.absorb(row, 1) {
                break;
            }
        }
        let first = s.generated.clone();
        assert!(!first.is_empty());
        s.first_token_tick = Some(1);
        s.rewind_for_replay();
        assert_eq!(s.pos, 0);
        assert!(s.generated.is_empty());
        assert_eq!(s.retries, 1);
        assert_eq!(s.first_token_tick, Some(1), "metrics survive the rewind");
        for row in &rows {
            if s.absorb(row, 2) {
                break;
            }
        }
        assert_eq!(s.generated, first, "replay must regenerate the same stream");
    }

    #[test]
    fn arena_recycles_buffers() {
        let mut a = StateArena::default();
        let mut s1 = a.take();
        s1.slot(0, &[4], true);
        assert_eq!((a.takes, a.misses), (1, 1));
        a.put(s1);
        let s2 = a.take();
        assert_eq!((a.takes, a.misses), (2, 1), "second take must reuse");
        assert_eq!(s2.tensors.len(), 1);
        a.put(s2);
        assert_eq!(a.reallocs(), 1);
    }
}
