//! Linear-MoE launcher CLI.
//!
//!   linear-moe train --tag small_gla --steps 200 --lr 3e-4 [--dp 2] ...
//!   linear-moe infer --tag tiny_bla --len 256
//!   linear-moe eval  --tag small_gla --ckpt path.ckpt
//!   linear-moe show-config [--tag tiny_gla]
//!
//! Hand-rolled arg parsing (offline build: no clap); every subcommand maps
//! onto library entry points so examples/ and benches/ share the code.

use std::collections::HashMap;

use anyhow::{Context, Result};
use linear_moe::collectives::{Comm, CommCfg};
use linear_moe::coordinator::ddp::{
    pjrt_model_factory, run_ddp_resilient, run_single, ResilientCfg,
};
use linear_moe::coordinator::moe_ep::{
    forward_ep, DispatchArena, EpCfg, EpStats, ExpertWeights, MoeGeom,
    ReferenceExperts, Strategy,
};
use linear_moe::coordinator::{checkpoint, metrics, obs};
use linear_moe::trace::TraceHandle;
use linear_moe::rng::Rng;
use linear_moe::data;
use linear_moe::fault::FaultPlan;
use linear_moe::inference::{greedy, Decoder, LsmDecoder};
use linear_moe::memcost;
use linear_moe::runtime::Runtime;
use linear_moe::serve::{
    poisson_trace, Engine, EngineCfg, FaultDecoder, RefLsmDecoder, Request,
    Sampling, ServeFaultPlan,
};
use linear_moe::tensor::Tensor;

/// Build a live tracer iff `--trace-out` was given (tracing off = zero
/// cost on the hot paths: every emission site is gated on `on()`).
fn trace_handle(f: &HashMap<String, String>) -> TraceHandle {
    if f.contains_key("trace-out") { TraceHandle::active() } else { TraceHandle::none() }
}

/// Write the JSONL + Perfetto exports and print the event summary when a
/// tracer is live and `--trace-out` named a path.
fn finish_trace(trace: &TraceHandle, path: Option<&String>) -> Result<()> {
    let (Some(t), Some(path)) = (trace.tracer(), path) else {
        return Ok(());
    };
    let (jsonl, perfetto) = t.write_outputs(path)?;
    print!("{}", t.summary());
    println!("trace: wrote {jsonl} and {perfetto} (open the .json in ui.perfetto.dev)");
    Ok(())
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn flag<T: std::str::FromStr>(m: &HashMap<String, String>, k: &str, default: T) -> T {
    m.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());

    match cmd {
        "train" => train(&dir, &flags),
        "infer" => infer(&dir, &flags),
        "serve" => serve_cmd(&dir, &flags),
        "eval" => eval_cmd(&dir, &flags),
        "show-config" => show_config(&dir, &flags),
        _ => {
            println!(
                "linear-moe <train|infer|serve|eval|show-config> [--flags]\n\
                 train:  --tag tiny_gla --steps 20 --lr 1e-3 --batch 2 --seq 128 \
                 [--dp N] [--grad-accum N] [--save ckpt.bin] [--curve out.csv]\n\
                 \x20       [--save-every K] [--max-restarts N] [--comm-timeout-ms MS]\n\
                 \x20       [--fault 'kill:rank=1,step=5;delay:rank=0,step=3,ms=50']\n\
                 \x20       [--ep N] [--moe-strategy loop|grouped|megablocks] \
                 [--moe-chunk E] [--moe-overlap true|false]\n\
                 \x20       (--ep runs the expert-parallel MoE engine over N ranks)\n\
                 \x20       [--trace-out t.json] -- write Perfetto + JSONL trace \
                 (train dp>1, --ep, serve)\n\
                 infer:  --tag tiny_bla --batch 4 --len 64\n\
                 serve:  --tag tiny_bla --requests 32 --batch 4 --max-new 32 \
                 [--prompt-len 8] [--arrival-gap 2.0]\n\
                 \x20       [--temp T] [--top-k K] [--preempt-after Q] \
                 [--max-pending N] [--seed S] [--backend auto|ref|pjrt]\n\
                 \x20       [--deadline TTL] [--retries N] [--trace-out t.json] \
                 [--fault 'step_err:step=30,lane=1;corrupt_state:req=3;\
                 stall:step=50,ticks=20']\n\
                 eval:   --tag tiny_gla --batch 2 --seq 128 [--batches 8]\n\
                 show-config: [--tag tiny_gla] -- print variants + memory model"
            );
            Ok(())
        }
    }
}

fn train(dir: &str, f: &HashMap<String, String>) -> Result<()> {
    if f.contains_key("ep") {
        return moe_ep_demo(f);
    }
    let tag: String = flag(f, "tag", "tiny_gla".to_string());
    let steps: usize = flag(f, "steps", 20);
    let lr: f32 = flag(f, "lr", 1e-3);
    let batch: usize = flag(f, "batch", 2);
    let seq: usize = flag(f, "seq", 128);
    let dp: usize = flag(f, "dp", 1);
    let grad_accum: usize = flag(f, "grad-accum", 1);
    let save_every: usize = flag(f, "save-every", 0);
    let comm_timeout_ms: u64 = flag(f, "comm-timeout-ms", 30_000);
    let max_restarts: usize = flag(f, "max-restarts", 3);
    let faults = match f.get("fault") {
        Some(spec) => std::sync::Arc::new(
            FaultPlan::parse(spec).context("parsing --fault")?,
        ),
        None => std::sync::Arc::new(FaultPlan::none()),
    };
    let trace = trace_handle(f);

    let rt = Runtime::new(dir)?;
    let vocab = rt.manifest.variant(&tag)?.config.vocab;
    drop(rt);
    let bf: linear_moe::coordinator::ddp::BatchFn =
        std::sync::Arc::new(move |idx, n| {
            let mut lm = data::ZipfLm::new(vocab, 500 + idx as u64);
            let b = data::batch_from_stream(&mut lm, batch, n);
            (b.tokens, b.targets)
        });
    let have_fwd_bwd = Runtime::new(dir)?
        .manifest
        .artifacts
        .contains_key(&format!("fwd_bwd_{tag}_b{batch}n{seq}"));
    let report = if dp > 1 {
        let ckpt_path = f
            .get("save")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::env::temp_dir().join(format!("lmoe_{tag}.ckpt")));
        run_ddp_resilient(
            &ResilientCfg {
                dp,
                batch,
                seq,
                lr,
                steps,
                save_every,
                max_restarts,
                comm_timeout: std::time::Duration::from_millis(comm_timeout_ms),
                backoff: std::time::Duration::from_millis(50),
                ckpt_path,
                faults,
                trace: trace.clone(),
            },
            pjrt_model_factory(dir, &tag, batch, seq),
            bf,
        )?
    } else if have_fwd_bwd && grad_accum > 1 {
        run_single(dir, &tag, batch, seq, lr, steps, bf, grad_accum)?
    } else {
        linear_moe::coordinator::ddp::run_fused(dir, &tag, batch, seq, lr, steps, bf, 10)?
    };
    let mut curve = metrics::LossCurve::new(&tag);
    for (i, l) in report.losses.iter().enumerate() {
        curve.push(i, *l);
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!("step {i:5}  loss {l:.4}");
        }
    }
    println!(
        "throughput: {:.0} tokens/s  (dp={dp}, traffic ag={} B rs={} B)",
        report.tokens_per_sec, report.traffic.0, report.traffic.1
    );
    if report.recoveries > 0 {
        println!("recoveries: {}", report.recoveries);
        for e in &report.fault_events {
            println!("  {e}");
        }
    }
    if let Some(h) = &report.health {
        println!(
            "health: heartbeats {:?}  restarts {}  comm {{timeouts {} peer-failures {} \
             kills {} delays {} dropped-ring {}}}",
            h.heartbeats, h.restarts, h.comm.timeouts, h.comm.peer_failures,
            h.comm.injected_kills, h.comm.injected_delays, h.comm.dropped_ring
        );
        let t = &h.traffic;
        println!(
            "traffic by kind: all_gather {} B/{} ops  reduce_scatter {} B/{} ops  \
             ring {} B/{} ops  all_to_all {} B/{} ops",
            t.all_gather_bytes, t.all_gather_ops,
            t.reduce_scatter_bytes, t.reduce_scatter_ops,
            t.ring_bytes, t.ring_ops,
            t.all_to_all_bytes, t.all_to_all_ops
        );
    }
    if let Some(path) = f.get("curve") {
        metrics::write_csv(path, &[&curve])?;
        println!("wrote {path}");
    }
    if let (Some(path), Some(params)) = (f.get("save"), &report.params) {
        checkpoint::save(path, &[("params", params)])?;
        println!("saved {path}");
    }
    if trace.on() && dp <= 1 {
        eprintln!("note: --trace-out instruments the dp>1 resilient path; trace is empty");
    }
    finish_trace(&trace, f.get("trace-out"))?;
    Ok(())
}

/// Drive the expert-parallel MoE engine end-to-end over `--ep` in-process
/// ranks with the pure-Rust reference backend (no artifacts needed):
/// routed dispatch all-to-all, chunked + overlapped expert execution,
/// return all-to-all, combine.  Reports overlap fraction and per-kind
/// traffic so the FSMoE-style pipelining is observable from the CLI.
fn moe_ep_demo(f: &HashMap<String, String>) -> Result<()> {
    let ep: usize = flag(f, "ep", 2);
    let strategy = Strategy::parse(&flag(f, "moe-strategy", "megablocks".to_string()))?;
    let chunk: usize = flag(f, "moe-chunk", 0);
    let overlap: bool = flag(f, "moe-overlap", true);
    let steps: usize = flag(f, "steps", 20);
    let batch: usize = flag(f, "batch", 2);
    let seq: usize = flag(f, "seq", 128);
    let d: usize = flag(f, "moe-d", 32);
    let n_experts: usize = flag(f, "moe-experts", 8);
    let top_k: usize = flag(f, "moe-topk", 2);
    let ff: usize = flag(f, "moe-ff", 64);
    anyhow::ensure!(ep >= 1, "--ep must be >= 1");
    anyhow::ensure!(n_experts % ep == 0, "--moe-experts must divide by --ep");
    let t_local = batch * seq / ep.max(1);
    anyhow::ensure!(t_local >= 1, "batch*seq too small for ep={ep}");
    let cap = (t_local * top_k).div_ceil(n_experts) * 2;
    let geom = MoeGeom { d, n_experts, top_k, cap, tile: cap.div_ceil(2).max(1) };
    let cfg = EpCfg { strategy, chunk, overlap };

    let mut rng = Rng::new(42);
    let weights = ExpertWeights::random(&mut rng, n_experts, d, ff);
    let backend0 = ReferenceExperts::new(weights);
    let trace = trace_handle(f);

    let (comm, handles) =
        Comm::new_with(ep, CommCfg { tracer: trace.clone(), ..Default::default() });
    let t0 = std::time::Instant::now();
    let joins: Vec<_> = handles
        .into_iter()
        .map(|h| {
            let backend = backend0.clone();
            std::thread::spawn(move || -> Result<EpStats> {
                let mut arena = DispatchArena::new();
                let mut rng = Rng::new(1000 + h.rank as u64);
                let mut total = EpStats::default();
                for step in 0..steps {
                    h.set_step(step);
                    let x = linear_moe::tensor::Tensor::f32(
                        &[t_local, geom.d],
                        (0..t_local * geom.d).map(|_| rng.normal()).collect(),
                    );
                    let mut gates = Vec::with_capacity(t_local * geom.top_k);
                    let mut idx = Vec::with_capacity(t_local * geom.top_k);
                    for _ in 0..t_local * geom.top_k {
                        idx.push(rng.below(geom.n_experts) as i32);
                        gates.push(rng.f32());
                    }
                    let (_y, stats) =
                        forward_ep(&h, &backend, &cfg, &geom, &gates, &idx, &x, &mut arena)?;
                    total.rounds = stats.rounds;
                    total.launches += stats.launches;
                    total.sent_rows += stats.sent_rows;
                    total.recv_rows += stats.recv_rows;
                    total.dropped_rows += stats.dropped_rows;
                    total.payload_bytes += stats.payload_bytes;
                    total.comm_wait += stats.comm_wait;
                    total.compute += stats.compute;
                    total.compute_overlapped += stats.compute_overlapped;
                }
                Ok(total)
            })
        })
        .collect();
    let mut per_rank = Vec::new();
    for (rank, j) in joins.into_iter().enumerate() {
        let s = j
            .join()
            .map_err(|_| anyhow::anyhow!("EP rank {rank} panicked"))?
            .with_context(|| format!("EP rank {rank}"))?;
        per_rank.push(s);
    }
    let dt = t0.elapsed().as_secs_f64();
    let s0 = &per_rank[0];
    println!(
        "moe-ep: ep={ep} strategy={strategy} chunk={} overlap={} rounds/step={}",
        chunk, overlap, s0.rounds
    );
    println!(
        "rank0 over {steps} steps: launches {}  sent {}  recv {}  dropped {}  \
         overlap {:.0}%  comm-wait {:.1} ms  compute {:.1} ms",
        s0.launches, s0.sent_rows, s0.recv_rows, s0.dropped_rows,
        100.0 * s0.overlap_frac(),
        s0.comm_wait.as_secs_f64() * 1e3,
        s0.compute.as_secs_f64() * 1e3
    );
    let t = comm.traffic_by_kind();
    println!(
        "tokens/s {:.0}  all_to_all {} B in {} ops (group-wide)",
        (batch * seq * steps) as f64 / dt,
        t.all_to_all_bytes, t.all_to_all_ops
    );
    if let Some(tr) = trace.tracer() {
        tr.with_metrics(|m| {
            for (rank, s) in per_rank.iter().enumerate() {
                obs::absorb_ep_stats(m, rank, s);
            }
            obs::absorb_traffic(m, &t);
        });
        // cross-check: overlap fraction re-derived from ep.expert spans
        // must agree with the hand-maintained EpStats counters
        if let Some(span_frac) = obs::span_overlap_frac(&tr.sorted_events()) {
            println!(
                "trace cross-check: span overlap {:.0}% (EpStats rank0 {:.0}%)",
                100.0 * span_frac,
                100.0 * s0.overlap_frac()
            );
        }
    }
    finish_trace(&trace, f.get("trace-out"))?;
    Ok(())
}

fn infer(dir: &str, f: &HashMap<String, String>) -> Result<()> {
    let tag: String = flag(f, "tag", "tiny_bla".to_string());
    let batch: usize = flag(f, "batch", 4);
    let len: usize = flag(f, "len", 64);
    let rt = Runtime::new(dir)?;
    let mut dec = LsmDecoder::new(&rt, &tag, batch)?;
    let mut tok = Tensor::i32(&[batch], vec![1; batch]);
    let t0 = std::time::Instant::now();
    for pos in 0..len {
        let logits = dec.step(&tok, pos as i32)?;
        tok = greedy(&logits)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "decoded {len} tokens x{batch} lanes in {dt:.2}s \
         ({:.1} tok/s/lane); state {} KiB (constant)",
        len as f64 / dt,
        dec.state_bytes() / 1024
    );
    Ok(())
}

/// Continuous-batching serving demo: a Poisson-ish arrival trace of
/// synthetic requests through the session-pool engine.  Uses the PJRT
/// LSM decoder when artifacts are available (or --backend pjrt), else
/// degrades to the artifact-free reference LSM backend (recorded in the
/// report).  `--fault` injects deterministic serving faults, `--deadline`
/// gives every request a TTL in ticks, `--retries` bounds fault replays.
fn serve_cmd(dir: &str, f: &HashMap<String, String>) -> Result<()> {
    let tag: String = flag(f, "tag", "tiny_bla".to_string());
    let requests: usize = flag(f, "requests", 32);
    let batch: usize = flag(f, "batch", 4);
    let max_new: usize = flag(f, "max-new", 32);
    let prompt_len: usize = flag(f, "prompt-len", 8);
    let gap: f64 = flag(f, "arrival-gap", 2.0);
    let temp: f32 = flag(f, "temp", 0.0);
    let top_k: usize = flag(f, "top-k", 0);
    let quantum: u64 = flag(f, "preempt-after", 0);
    let max_pending: usize = flag(f, "max-pending", 1024);
    let seed: u64 = flag(f, "seed", 7);
    let backend: String = flag(f, "backend", "auto".to_string());
    let ttl: u64 = flag(f, "deadline", 0);
    let max_retries: u32 = flag(f, "retries", 2);
    let plan = match f.get("fault") {
        Some(spec) => std::sync::Arc::new(
            ServeFaultPlan::parse(spec).context("parsing --fault")?,
        ),
        None => std::sync::Arc::new(ServeFaultPlan::none()),
    };
    anyhow::ensure!(batch >= 1 && requests >= 1 && prompt_len >= 1 && max_new >= 1);
    let sampling = if top_k > 0 {
        Sampling::TopK { k: top_k, temp: temp.max(1e-3) }
    } else if temp > 0.0 {
        Sampling::Temperature { temp }
    } else {
        Sampling::Greedy
    };
    let trace = trace_handle(f);
    let cfg = EngineCfg {
        max_pending,
        preempt_after: (quantum > 0).then_some(quantum),
        max_retries,
        fault: plan.clone(),
        trace,
        ..Default::default()
    };
    let ttl = (ttl > 0).then_some(ttl);

    let pjrt = match backend.as_str() {
        "ref" => None,
        _ => Runtime::new(dir)
            .and_then(|rt| {
                let dec = LsmDecoder::new(&rt, &tag, batch)?;
                Ok((dec, rt))
            })
            .map_err(|e| {
                if backend == "pjrt" {
                    eprintln!("error: PJRT backend requested but unavailable: {e:#}");
                }
                e
            })
            .ok(),
    };
    match pjrt {
        Some((dec, rt)) => {
            let vocab = rt.manifest.variant(&tag)?.config.vocab;
            println!("serve: PJRT LSM decoder, tag {tag}, {batch} lanes");
            let dec = FaultDecoder::new(dec, plan);
            drive_serve(
                dec, vocab, requests, prompt_len, max_new, gap, sampling, seed, ttl,
                cfg, false, f.get("trace-out"),
            )
        }
        None if backend == "pjrt" => anyhow::bail!("--backend pjrt needs artifacts"),
        None => {
            // degraded only when PJRT was attempted and lost (auto mode);
            // --backend ref is an explicit choice, not a degradation
            let degraded = backend != "ref";
            println!(
                "serve: reference LSM backend ({batch} lanes; {})",
                if degraded { "degraded from pjrt: no artifacts" } else { "--backend ref" }
            );
            let dec = FaultDecoder::new(RefLsmDecoder::new(batch, 64, 16, seed), plan);
            drive_serve(
                dec, 64, requests, prompt_len, max_new, gap, sampling, seed, ttl, cfg,
                degraded, f.get("trace-out"),
            )
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn drive_serve<D: Decoder>(
    dec: D,
    vocab: usize,
    requests: usize,
    prompt_len: usize,
    max_new: usize,
    gap: f64,
    sampling: Sampling,
    seed: u64,
    ttl: Option<u64>,
    cfg: EngineCfg,
    degraded: bool,
    trace_out: Option<&String>,
) -> Result<()> {
    let trace = cfg.trace.clone();
    let mut rng = Rng::new(seed);
    let mut prompt_rng = Rng::new(seed ^ 0xABCD);
    let trace = poisson_trace(&mut rng, requests, gap, |id| Request {
        id,
        prompt: (0..prompt_len)
            .map(|_| prompt_rng.below(vocab) as i32)
            .collect(),
        max_new,
        eos: None,
        sampling,
        seed: seed.wrapping_add(id),
        ttl,
    });
    let mut engine = Engine::new(dec, cfg)?;
    let mut report = engine.run_trace(&trace)?;
    report.degraded = degraded;
    let waits: Vec<f64> = report
        .results
        .iter()
        .filter_map(|r| r.queue_wait().map(|w| w as f64))
        .collect();
    let ttfts: Vec<f64> = report
        .results
        .iter()
        .filter_map(|r| r.ttft().map(|t| t as f64))
        .collect();
    let wait = metrics::Summary::of(&waits);
    let ttft = metrics::Summary::of(&ttfts);
    let o = &report.outcomes;
    println!(
        "served {} requests, {} tokens in {:.3}s ({:.0} tok/s goodput; {} decoder steps)",
        report.results.len(),
        report.tokens_out,
        report.wall_secs,
        report.tokens_per_sec(),
        report.steps
    );
    println!(
        "outcomes: finished {} (recovered {})  expired {}  shed {}  failed {}{}",
        o.finished,
        o.recovered,
        o.expired,
        o.shed,
        o.failed,
        if report.degraded { "  [degraded backend]" } else { "" }
    );
    if report.faults_injected + report.stalled_ticks + report.corruptions_injected > 0 {
        println!(
            "faults: step errors {}  stalled ticks {}  state corruptions {}  \
             crc failures {}  retries {}",
            report.faults_injected,
            report.stalled_ticks,
            report.corruptions_injected,
            report.crc_failures,
            report.results.iter().map(|r| r.retries as u64).sum::<u64>()
        );
    }
    if ttl.is_some() {
        let misses: Vec<f64> = report
            .results
            .iter()
            .filter_map(|r| r.deadline_miss().map(|m| m as f64))
            .collect();
        let m = metrics::Summary::of(&misses);
        println!(
            "deadline misses: {} of {} (ticks late: mean {:.1} p95 {:.0} max {:.0})",
            m.n,
            report.results.len(),
            m.mean,
            m.p95,
            m.max
        );
    }
    println!(
        "occupancy {:.2}/{} lanes  swaps {} ({} KiB)  state reallocs {}  \
         bounced submits {}",
        report.occupancy(),
        engine.dec.lanes(),
        report.swaps,
        report.swap_bytes / 1024,
        report.state_reallocs,
        report.rejected
    );
    println!(
        "queue wait ticks: mean {:.1} min {:.0} p50 {:.0} p95 {:.0} p99 {:.0} max {:.0}",
        wait.mean, wait.min, wait.p50, wait.p95, wait.p99, wait.max
    );
    println!(
        "ttft ticks:       mean {:.1} min {:.0} p50 {:.0} p95 {:.0} p99 {:.0} max {:.0}",
        ttft.mean, ttft.min, ttft.p50, ttft.p95, ttft.p99, ttft.max
    );
    println!(
        "per-lane state {} B (constant in position for LSM)",
        engine.dec.lane_state_bytes(prompt_len + max_new)
    );
    if let Some(t) = trace.tracer() {
        // cross-check: occupancy re-derived from engine.step spans is a
        // ratio of the same integer counters as ServeReport::occupancy
        if let Some(occ) = obs::span_occupancy(&t.sorted_events()) {
            println!(
                "trace cross-check: span occupancy {:.4} (report {:.4})",
                occ,
                report.occupancy()
            );
        }
    }
    finish_trace(&trace, trace_out)?;
    Ok(())
}

fn eval_cmd(dir: &str, f: &HashMap<String, String>) -> Result<()> {
    let tag: String = flag(f, "tag", "tiny_gla".to_string());
    let batch: usize = flag(f, "batch", 2);
    let seq: usize = flag(f, "seq", 128);
    let batches: usize = flag(f, "batches", 8);
    let rt = Runtime::new(dir)?;
    let params = if let Some(path) = f.get("ckpt") {
        let mut bundles = checkpoint::load(path)?;
        checkpoint::take_bundle(&mut bundles, "params")
            .with_context(|| format!("checkpoint {path} has no 'params' bundle"))?
    } else {
        rt.init_params(&tag, 0)?
    };
    let ppl = linear_moe::eval::perplexity(&rt, &tag, &params, batch, seq, batches, 77)?;
    println!("{tag}: held-out perplexity {ppl:.2} over {batches} batches");
    Ok(())
}

fn show_config(dir: &str, f: &HashMap<String, String>) -> Result<()> {
    let rt = Runtime::new(dir)?;
    let filter = f.get("tag");
    let mut table = metrics::Table::new(&[
        "variant", "layout", "lsm", "d_model", "experts", "params",
        "activated", "train MiB (b4 n512)",
    ]);
    for (tag, v) in &rt.manifest.variants {
        if let Some(want) = filter {
            if *want != *tag {
                continue;
            }
        }
        let p = memcost::ParallelCfg::single();
        let mib = memcost::mib(memcost::train_bytes(&v.config, 4, 512, &p, false));
        table.row(&[
            tag.clone(),
            v.config.layout.clone(),
            v.config.lsm.clone(),
            v.config.d_model.to_string(),
            format!("{}/{}", v.config.top_k, v.config.n_experts),
            v.params_total.to_string(),
            v.params_activated.to_string(),
            format!("{mib:.1}"),
        ]);
    }
    table.print();
    Ok(())
}
