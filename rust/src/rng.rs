//! Deterministic RNG (SplitMix64 + xoshiro256**) used by the data
//! pipeline, synthetic workloads, and the in-tree property-test harness.
//! No external crates (offline build); fully reproducible across runs.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Zipf-distributed rank in [0, n) with exponent `a` (rejection-free
    /// approximation via inverse CDF on a precomputed table is overkill;
    /// this inversion is exact enough for corpus synthesis).
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        // inverse-transform on the continuous Zipf approximation
        let u = self.f32() as f64;
        let h = |x: f64| ((x + 1.0).powf(1.0 - a) - 1.0) / (1.0 - a);
        let hmax = h(n as f64);
        let x = ((1.0 - a) * u * hmax + 1.0).powf(1.0 / (1.0 - a)) - 1.0;
        (x as usize).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

/// Tiny property-test harness (offline substitute for proptest): run
/// `cases` seeded cases of `f`; on failure report the seed so the case can
/// be replayed with `check_one`.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for c in 0..cases {
        let seed = 0xC0FFEE ^ (c.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name:?} failed on case {c} (seed {seed:#x}): {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 10_000;
        let mean: f32 = (0..n).map(|_| r.f32()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > 500);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
