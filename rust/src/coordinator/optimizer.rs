//! Optimizer: Adam with bias correction, in two interchangeable backends,
//! plus the ZeRO-1 **distributed optimizer** (Megatron-Core's
//! "Distributed Optimizer", paper §2.2.3): each DP rank owns 1/dp of the
//! flat parameter vector, updates only its shard, then shards are
//! all-gathered back into full parameters.
//!
//! Backends:
//!  - `RustAdam`: scalar loop on host buffers (no PJRT round-trip; the
//!    default — profiling showed the HLO round-trip dominates at small
//!    bucket sizes, see EXPERIMENTS.md §Perf).
//!  - `HloAdam`: executes the `adam_bucket_{n}` artifacts; numerically
//!    identical (tested), kept as the cross-check and the path a real
//!    accelerator deployment would use.

use anyhow::Result;

use crate::collectives::CommHandle;
use crate::runtime::Runtime;
use crate::tensor::{Bundle, Tensor};

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.95;
pub const EPS: f32 = 1e-8;

/// Flat Adam state over `n` parameters.
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// In-place Adam on a flat slice (one shard).  `step` is 1-based.
pub fn adam_step_flat(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: i32,
    lr: f32,
) {
    let bc1 = 1.0 - B1.powi(step);
    let bc2 = 1.0 - B2.powi(step);
    for i in 0..p.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        p[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

/// HLO-backed Adam over fixed-size buckets (the `adam_bucket_*` artifacts).
pub struct HloAdam {
    bucket: usize,
    exe: std::rc::Rc<crate::runtime::Executable>,
}

impl HloAdam {
    pub fn new(rt: &Runtime, bucket: usize) -> Result<Self> {
        Ok(HloAdam { bucket, exe: rt.load(&format!("adam_bucket_{bucket}"))? })
    }

    /// Apply Adam to a flat vector by slicing it into buckets (the last
    /// bucket is zero-padded; padding lanes carry zero grads so they stay
    /// zero).
    pub fn step_flat(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        step: i32,
        lr: f32,
    ) -> Result<()> {
        let n = p.len();
        let bk = self.bucket;
        let step_t = Tensor::scalar_i32(step);
        let lr_t = Tensor::scalar_f32(lr);
        let mut off = 0;
        while off < n {
            let len = bk.min(n - off);
            let pad = bk - len;
            let mk = |src: &[f32]| {
                let mut buf = src[off..off + len].to_vec();
                buf.resize(len + pad, 0.0);
                Tensor::f32(&[bk], buf)
            };
            let out = self.exe.run(&[&mk(p), &mk(g), &mk(m), &mk(v),
                                     &step_t, &lr_t])?;
            p[off..off + len].copy_from_slice(&out[0].as_f32()?[..len]);
            m[off..off + len].copy_from_slice(&out[1].as_f32()?[..len]);
            v[off..off + len].copy_from_slice(&out[2].as_f32()?[..len]);
            off += len;
        }
        Ok(())
    }
}

/// ZeRO-1 distributed optimizer: rank owns `[lo, hi)` of the padded flat
/// parameter vector.  `step_and_allgather` performs the local Adam update
/// and reassembles full params via the DP group's all-gather.
pub struct DistributedOptimizer {
    pub world: usize,
    pub rank_in_dp: usize,
    pub shard: usize,
    pub padded: usize,
    state: AdamState,
}

impl DistributedOptimizer {
    pub fn new(total_params: usize, dp_world: usize, rank_in_dp: usize) -> Self {
        let shard = total_params.div_ceil(dp_world);
        DistributedOptimizer {
            world: dp_world,
            rank_in_dp,
            shard,
            padded: shard * dp_world,
            state: AdamState::new(shard),
        }
    }

    /// Bytes of optimizer state held by this rank (memcost cross-check).
    pub fn state_bytes(&self) -> usize {
        2 * self.shard * 4
    }

    /// This rank's Adam shard (m, v) and the 1-based step counter --
    /// checkpointed by the resilient trainer so a rollback restores the
    /// optimizer exactly, not just the parameters.
    pub fn shard_state(&self) -> (&[f32], &[f32], i32) {
        (&self.state.m, &self.state.v, self.state.step)
    }

    /// Restore this rank's shard from the full padded m/v vectors of a
    /// checkpoint (inverse of gathering `shard_state` across ranks).
    pub fn restore_from_full(&mut self, m_full: &[f32], v_full: &[f32], step: i32) -> Result<()> {
        anyhow::ensure!(
            m_full.len() == self.padded && v_full.len() == self.padded,
            "optimizer state length {} / {} != padded {} (dp changed between runs?)",
            m_full.len(), v_full.len(), self.padded
        );
        let lo = self.rank_in_dp * self.shard;
        self.state.m.copy_from_slice(&m_full[lo..lo + self.shard]);
        self.state.v.copy_from_slice(&v_full[lo..lo + self.shard]);
        self.state.step = step;
        Ok(())
    }

    /// One distributed step: update the local shard from the (already
    /// all-reduced) gradient, then all-gather shards into full params.
    pub fn step_and_allgather(
        &mut self,
        comm: &CommHandle,
        params: &mut Bundle,
        grads: &Bundle,
        lr: f32,
    ) -> Result<()> {
        let (mut flat_p, _) = params.flatten_f32()?;
        let (mut flat_g, _) = grads.flatten_f32()?;
        flat_p.resize(self.padded, 0.0);
        flat_g.resize(self.padded, 0.0);
        let lo = self.rank_in_dp * self.shard;
        let hi = lo + self.shard;
        self.state.step += 1;
        adam_step_flat(
            &mut flat_p[lo..hi],
            &flat_g[lo..hi],
            &mut self.state.m,
            &mut self.state.v,
            self.state.step,
            lr,
        );
        // All-gather updated shards (rank order) into the full vector.
        let local = Tensor::f32(&[self.shard], flat_p[lo..hi].to_vec());
        let all = comm.all_gather(local)?;
        let mut full = Vec::with_capacity(self.padded);
        for t in &all {
            full.extend_from_slice(t.as_f32()?);
        }
        full.truncate(params.numel());
        params.unflatten_f32(&full)?;
        Ok(())
    }
}

/// Single-worker convenience: full (non-sharded) Rust Adam over a Bundle.
pub struct LocalAdam {
    state: AdamState,
}

impl LocalAdam {
    pub fn new(n: usize) -> Self {
        LocalAdam { state: AdamState::new(n) }
    }

    pub fn step(&mut self, params: &mut Bundle, grads: &Bundle, lr: f32) -> Result<()> {
        let (mut p, _) = params.flatten_f32()?;
        let (g, _) = grads.flatten_f32()?;
        self.state.step += 1;
        adam_step_flat(&mut p, &g, &mut self.state.m, &mut self.state.v,
                       self.state.step, lr);
        params.unflatten_f32(&p)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_matches_closed_form_first_step() {
        let mut p = vec![1.0f32, -2.0];
        let g = vec![0.5f32, -0.25];
        let mut m = vec![0.0; 2];
        let mut v = vec![0.0; 2];
        adam_step_flat(&mut p, &g, &mut m, &mut v, 1, 0.1);
        // step 1: mhat = g, vhat = g^2  =>  p -= lr * sign-ish(g)
        for (i, &gi) in g.iter().enumerate() {
            let want = [1.0f32, -2.0][i] - 0.1 * gi / (gi.abs() + EPS);
            assert!((p[i] - want).abs() < 1e-5, "{} vs {}", p[i], want);
        }
    }

    #[test]
    fn zero_grad_is_identity() {
        let mut p = vec![3.0f32; 8];
        let g = vec![0.0f32; 8];
        let mut m = vec![0.0; 8];
        let mut v = vec![0.0; 8];
        adam_step_flat(&mut p, &g, &mut m, &mut v, 1, 0.1);
        assert!(p.iter().all(|&x| (x - 3.0).abs() < 1e-7));
    }
}
