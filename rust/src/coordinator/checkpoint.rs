//! Binary checkpointing for parameter / optimizer-state bundles.
//!
//! Format v2 (little-endian):
//!   magic "LMOE" | version u32 = 2 | n_bundles u32 |
//!   per bundle: name_len u32 | name | n_tensors u32 |
//!     per tensor: dtype u8 (0=f32, 1=i32) | ndim u32 | dims u64* | data |
//!   crc32 u32   -- IEEE CRC-32 over every preceding byte (magic included)
//!
//! Hardening (this is the recovery root of the fault-tolerant trainer, so
//! it must survive exactly the crashes it exists to fix):
//!  - **atomic writes**: serialize to a buffer, write to a temp file in the
//!    same directory, fsync, then rename over the target -- a crash mid-save
//!    can never leave a half-written checkpoint under the real name;
//!  - **integrity**: the CRC-32 trailer rejects truncated and bit-flipped
//!    files instead of misparsing them;
//!  - **allocation caps**: every declared count/shape is validated against
//!    hard caps and the actual remaining file size before `Vec` allocation,
//!    so a garbage header errors instead of attempting a multi-GiB alloc;
//!  - **rotation + fallback**: [`save_rotating`] keeps the previous good
//!    file as `<path>.prev`; [`load_with_fallback`] transparently falls
//!    back to it when the primary is corrupt.
//!
//! v1 files (no CRC) remain readable; the caps apply to them too.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::fault::{Fault, FaultPlan};
use crate::tensor::{Bundle, Data, Tensor};

const MAGIC: &[u8; 4] = b"LMOE";
const V1: u32 = 1;
const VERSION: u32 = 2;

/// Caps on header-declared quantities; anything larger is a corrupt or
/// adversarial file, not a real checkpoint.
const MAX_BUNDLES: usize = 4096;
const MAX_NAME_LEN: usize = 4096;
const MAX_TENSORS: usize = 1 << 20;
const MAX_NDIM: usize = 16;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3).  Bitwise implementation: no table, no dependency;
// checkpoints here are small enough that throughput is irrelevant.
// ---------------------------------------------------------------------------

/// Streaming CRC-32 hasher: feed byte chunks with [`Crc32::update`], read
/// the digest with [`Crc32::finish`].  Used by the checkpoint trailer and
/// by the serving engine to checksum lane-state images without staging
/// them into a contiguous buffer first.
#[derive(Clone, Debug)]
pub struct Crc32 {
    crc: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { crc: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.crc ^= b as u32;
            for _ in 0..8 {
                let mask = (self.crc & 1).wrapping_neg();
                self.crc = (self.crc >> 1) ^ (0xEDB8_8320 & mask);
            }
        }
    }

    pub fn finish(&self) -> u32 {
        !self.crc
    }
}

pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(data);
    h.finish()
}

/// `<path>.prev`: where [`save_rotating`] parks the previous good file.
pub fn prev_path(path: impl AsRef<Path>) -> PathBuf {
    let p = path.as_ref();
    let mut s = p.as_os_str().to_os_string();
    s.push(".prev");
    PathBuf::from(s)
}

// ---------------------------------------------------------------------------
// Save.
// ---------------------------------------------------------------------------

fn serialize(bundles: &[(&str, &Bundle)]) -> Vec<u8> {
    let mut w: Vec<u8> = Vec::new();
    w.extend_from_slice(MAGIC);
    w.extend_from_slice(&VERSION.to_le_bytes());
    w.extend_from_slice(&(bundles.len() as u32).to_le_bytes());
    for (name, b) in bundles {
        let nb = name.as_bytes();
        w.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        w.extend_from_slice(nb);
        w.extend_from_slice(&(b.tensors.len() as u32).to_le_bytes());
        for t in &b.tensors {
            let dtype: u8 = if t.is_f32() { 0 } else { 1 };
            w.push(dtype);
            w.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                w.extend_from_slice(&(d as u64).to_le_bytes());
            }
            match &t.data {
                Data::F32(v) => {
                    for x in v {
                        w.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Data::I32(v) => {
                    for x in v {
                        w.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
    }
    let crc = crc32(&w);
    w.extend_from_slice(&crc.to_le_bytes());
    w
}

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = {
        let mut s = path.as_os_str().to_os_string();
        s.push(&format!(".tmp.{}", std::process::id()));
        PathBuf::from(s)
    };
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Atomic, CRC-protected save (format v2).
pub fn save(path: impl AsRef<Path>, bundles: &[(&str, &Bundle)]) -> Result<()> {
    write_atomic(path.as_ref(), &serialize(bundles))
}

/// Save with fault injection: a pending `CorruptCheckpoint` fault flips one
/// byte of the serialized image before it hits disk (still atomically --
/// the corruption model is "bad disk / bad DMA", not "partial write",
/// which `save` already cannot produce).
pub fn save_with_faults(
    path: impl AsRef<Path>,
    bundles: &[(&str, &Bundle)],
    faults: &FaultPlan,
) -> Result<()> {
    let mut bytes = serialize(bundles);
    if let Some(Fault::CorruptCheckpoint { offset }) = faults.take_corrupt_ckpt() {
        let i = offset % bytes.len();
        bytes[i] ^= 0xFF;
    }
    write_atomic(path.as_ref(), &bytes)
}

/// Rotate-then-save: the existing file (if any) becomes `<path>.prev`, so
/// one good generation always survives a corrupted write.
pub fn save_rotating(
    path: impl AsRef<Path>,
    bundles: &[(&str, &Bundle)],
    faults: &FaultPlan,
) -> Result<()> {
    let path = path.as_ref();
    if path.exists() {
        std::fs::rename(path, prev_path(path))
            .with_context(|| format!("rotating {path:?}"))?;
    }
    save_with_faults(path, bundles, faults)
}

// ---------------------------------------------------------------------------
// Load.
// ---------------------------------------------------------------------------

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.buf.len() - self.pos,
            "checkpoint truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn parse_body(cur: &mut Cur) -> Result<Vec<(String, Bundle)>> {
    let n_bundles = cur.u32()? as usize;
    ensure!(n_bundles <= MAX_BUNDLES, "implausible bundle count {n_bundles}");
    let mut out = Vec::with_capacity(n_bundles);
    for _ in 0..n_bundles {
        let name_len = cur.u32()? as usize;
        ensure!(name_len <= MAX_NAME_LEN, "implausible name length {name_len}");
        let name = String::from_utf8(cur.take(name_len)?.to_vec())
            .context("bundle name is not UTF-8")?;
        let n_tensors = cur.u32()? as usize;
        ensure!(n_tensors <= MAX_TENSORS, "implausible tensor count {n_tensors}");
        // with_capacity is safe here: n_tensors is capped and each tensor
        // needs >= 6 header bytes, checked against the file as we go
        let mut tensors = Vec::with_capacity(n_tensors.min(cur.remaining() / 6 + 1));
        for _ in 0..n_tensors {
            let dtype = cur.u8()?;
            let ndim = cur.u32()? as usize;
            ensure!(ndim <= MAX_NDIM, "implausible rank {ndim}");
            let mut shape = Vec::with_capacity(ndim);
            let mut numel: usize = 1;
            for _ in 0..ndim {
                let d = cur.u64()?;
                let d = usize::try_from(d)
                    .with_context(|| format!("dim {d} overflows usize"))?;
                numel = numel
                    .checked_mul(d)
                    .with_context(|| format!("shape {shape:?} x {d} overflows"))?;
                shape.push(d);
            }
            // the data must actually be present before we allocate for it
            let nbytes = numel
                .checked_mul(4)
                .context("tensor byte size overflows")?;
            ensure!(
                nbytes <= cur.remaining(),
                "tensor claims {nbytes} bytes but only {} remain (corrupt header?)",
                cur.remaining()
            );
            let raw = cur.take(nbytes)?;
            let t = match dtype {
                0 => Tensor::f32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                1 => Tensor::i32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                d => bail!("bad dtype tag {d}"),
            };
            tensors.push(t);
        }
        out.push((name, Bundle::new(tensors)));
    }
    ensure!(cur.remaining() == 0, "{} trailing bytes after last bundle", cur.remaining());
    Ok(out)
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Bundle)>> {
    let path = path.as_ref();
    let buf = std::fs::read(path).with_context(|| format!("opening {path:?}"))?;
    let mut cur = Cur { buf: &buf, pos: 0 };
    let magic = cur.take(4)?;
    if magic != MAGIC {
        bail!("not a Linear-MoE checkpoint");
    }
    let version = cur.u32()?;
    match version {
        V1 => parse_body(&mut cur),
        VERSION => {
            ensure!(buf.len() >= 12, "checkpoint truncated before CRC trailer");
            let body = &buf[..buf.len() - 4];
            let stored = u32::from_le_bytes([
                buf[buf.len() - 4],
                buf[buf.len() - 3],
                buf[buf.len() - 2],
                buf[buf.len() - 1],
            ]);
            let actual = crc32(body);
            ensure!(
                stored == actual,
                "checkpoint CRC mismatch (stored {stored:#010x}, computed {actual:#010x}): \
                 file is truncated or corrupt"
            );
            let mut cur = Cur { buf: body, pos: 8 }; // past magic + version
            parse_body(&mut cur)
        }
        v => bail!("unsupported checkpoint version {v}"),
    }
}

/// Load `path`, falling back to `<path>.prev` if the primary is missing or
/// corrupt.  Returns the bundles and whether the fallback was used.
pub fn load_with_fallback(path: impl AsRef<Path>) -> Result<(Vec<(String, Bundle)>, bool)> {
    let path = path.as_ref();
    match load(path) {
        Ok(b) => Ok((b, false)),
        Err(primary) => {
            let prev = prev_path(path);
            match load(&prev) {
                Ok(b) => Ok((b, true)),
                Err(fallback) => bail!(
                    "checkpoint {path:?} unusable ({primary:#}) and fallback {prev:?} \
                     unusable ({fallback:#})"
                ),
            }
        }
    }
}

/// Pull one bundle out by name (order-independent lookup).
pub fn take_bundle(bundles: &mut Vec<(String, Bundle)>, name: &str) -> Option<Bundle> {
    let i = bundles.iter().position(|(n, _)| n == name)?;
    Some(bundles.remove(i).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lmoe_ckpt_test").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample() -> (Bundle, Bundle) {
        let params = Bundle::new(vec![
            Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::i32(&[2], vec![7, 8]),
        ]);
        let opt = Bundle::new(vec![Tensor::f32(&[4], vec![0.1, 0.2, 0.3, 0.4])]);
        (params, opt)
    }

    #[test]
    fn roundtrip() {
        let path = tdir("roundtrip").join("test.ckpt");
        let (params, opt) = sample();
        save(&path, &[("params", &params), ("opt_m", &opt)]).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "params");
        assert_eq!(loaded[0].1.tensors, params.tensors);
        assert_eq!(loaded[1].1.tensors, opt.tensors);
    }

    #[test]
    fn streaming_crc_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let mut h = Crc32::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), crc32(&data));
        assert_eq!(Crc32::new().finish(), crc32(&[]));
        // IEEE CRC-32 check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn rejects_garbage() {
        let path = tdir("garbage").join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let path = tdir("trunc").join("t.ckpt");
        let (params, _) = sample();
        save(&path, &[("params", &params)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for cut in [bytes.len() - 1, bytes.len() - 5, bytes.len() / 2, 9] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncation at {cut} must be rejected");
        }
    }

    #[test]
    fn rejects_bit_flip_via_crc() {
        let path = tdir("flip").join("t.ckpt");
        let (params, _) = sample();
        save(&path, &[("params", &params)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // flip one payload byte (past the 12-byte header)
        for i in [12usize, bytes.len() / 2, bytes.len() - 6] {
            let mut b = bytes.clone();
            b[i] ^= 0x01;
            std::fs::write(&path, &b).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains("CRC"), "byte {i}: expected CRC error, got {err}");
        }
    }

    #[test]
    fn reads_v1_files() {
        // handcraft a v1 file: no CRC trailer
        let path = tdir("v1").join("old.ckpt");
        let mut w: Vec<u8> = Vec::new();
        w.extend_from_slice(MAGIC);
        w.extend_from_slice(&1u32.to_le_bytes()); // version 1
        w.extend_from_slice(&1u32.to_le_bytes()); // 1 bundle
        w.extend_from_slice(&6u32.to_le_bytes());
        w.extend_from_slice(b"params");
        w.extend_from_slice(&1u32.to_le_bytes()); // 1 tensor
        w.push(0); // f32
        w.extend_from_slice(&1u32.to_le_bytes()); // ndim 1
        w.extend_from_slice(&2u64.to_le_bytes()); // dim 2
        w.extend_from_slice(&1.5f32.to_le_bytes());
        w.extend_from_slice(&(-2.5f32).to_le_bytes());
        std::fs::write(&path, &w).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded[0].0, "params");
        assert_eq!(loaded[0].1.tensors[0], Tensor::f32(&[2], vec![1.5, -2.5]));
    }

    #[test]
    fn rejects_adversarial_header_without_allocating() {
        // v1 header declaring a ~4 EiB tensor: must error, not OOM
        let path = tdir("adversarial").join("evil.ckpt");
        let mut w: Vec<u8> = Vec::new();
        w.extend_from_slice(MAGIC);
        w.extend_from_slice(&1u32.to_le_bytes());
        w.extend_from_slice(&1u32.to_le_bytes());
        w.extend_from_slice(&1u32.to_le_bytes());
        w.push(b'p');
        w.extend_from_slice(&1u32.to_le_bytes());
        w.push(0);
        w.extend_from_slice(&1u32.to_le_bytes());
        w.extend_from_slice(&(1u64 << 60).to_le_bytes());
        std::fs::write(&path, &w).unwrap();
        let t0 = std::time::Instant::now();
        assert!(load(&path).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(1));

        // absurd counts are rejected by caps, not trusted by with_capacity
        for (field, val) in [(8usize, u32::MAX), (12 + 5, u32::MAX)] {
            let path = tdir("adversarial").join(format!("evil{field}.ckpt"));
            let mut w: Vec<u8> = Vec::new();
            w.extend_from_slice(MAGIC);
            w.extend_from_slice(&1u32.to_le_bytes());
            w.extend_from_slice(&1u32.to_le_bytes()); // n_bundles
            w.extend_from_slice(&1u32.to_le_bytes()); // name_len
            w.push(b'p');
            w.extend_from_slice(&1u32.to_le_bytes()); // n_tensors
            w[field..field + 4].copy_from_slice(&val.to_le_bytes());
            std::fs::write(&path, &w).unwrap();
            assert!(load(&path).is_err());
        }
    }

    #[test]
    fn rotation_and_fallback() {
        let dir = tdir("rotate");
        let path = dir.join("m.ckpt");
        let (a, b) = sample();
        let none = FaultPlan::none();
        save_rotating(&path, &[("params", &a)], &none).unwrap();
        save_rotating(&path, &[("params", &b)], &none).unwrap();
        assert!(prev_path(&path).exists());
        // pristine primary: no fallback
        let (loaded, used_prev) = load_with_fallback(&path).unwrap();
        assert!(!used_prev);
        assert_eq!(loaded[0].1.tensors, b.tensors);
        // corrupt primary: fall back to prev (= first generation)
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (loaded, used_prev) = load_with_fallback(&path).unwrap();
        assert!(used_prev);
        assert_eq!(loaded[0].1.tensors, a.tensors);
    }

    #[test]
    fn injected_corruption_is_caught_by_crc() {
        let path = tdir("inject").join("m.ckpt");
        let (a, _) = sample();
        let faults = FaultPlan::parse("corrupt_ckpt:offset=17").unwrap();
        save_with_faults(&path, &[("params", &a)], &faults).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC") || err.contains("truncated"), "{err}");
        // one-shot: the next save is clean
        save_with_faults(&path, &[("params", &a)], &faults).unwrap();
        assert!(load(&path).is_ok());
    }

    #[test]
    fn no_tmp_files_left_behind() {
        let dir = tdir("atomic");
        let path = dir.join("m.ckpt");
        let (a, _) = sample();
        save(&path, &[("params", &a)]).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn take_bundle_by_name() {
        let (a, b) = sample();
        let path = tdir("take").join("m.ckpt");
        save(&path, &[("opt_m", &b), ("params", &a)]).unwrap();
        let mut loaded = load(&path).unwrap();
        let p = take_bundle(&mut loaded, "params").unwrap();
        assert_eq!(p.tensors, a.tensors);
        assert!(take_bundle(&mut loaded, "params").is_none());
        assert!(take_bundle(&mut loaded, "opt_m").is_some());
    }
}
