//! Binary checkpointing for parameter / optimizer-state bundles.
//!
//! Format (little-endian):
//!   magic "LMOE" | version u32 | n_tensors u32 |
//!   per tensor: dtype u8 (0=f32, 1=i32) | ndim u32 | dims u64* | data
//!
//! Deterministic, self-describing, resumable mid-run; the `train`
//! subcommand writes one every --save-every steps.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{Bundle, Data, Tensor};

const MAGIC: &[u8; 4] = b"LMOE";
const VERSION: u32 = 1;

pub fn save(path: impl AsRef<Path>, bundles: &[(&str, &Bundle)]) -> Result<()> {
    let f = File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(bundles.len() as u32).to_le_bytes())?;
    for (name, b) in bundles {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(b.tensors.len() as u32).to_le_bytes())?;
        for t in &b.tensors {
            let dtype: u8 = if t.is_f32() { 0 } else { 1 };
            w.write_all(&[dtype])?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            match &t.data {
                Data::F32(v) => {
                    for x in v {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                Data::I32(v) => {
                    for x in v {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Bundle)>> {
    let f = File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a Linear-MoE checkpoint");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let n_bundles = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n_bundles);
    for _ in 0..n_bundles {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let n_tensors = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let mut dtype = [0u8; 1];
            r.read_exact(&mut dtype)?;
            let ndim = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut raw = vec![0u8; numel * 4];
            r.read_exact(&mut raw)?;
            let t = match dtype[0] {
                0 => Tensor::f32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                1 => Tensor::i32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                d => bail!("bad dtype tag {d}"),
            };
            tensors.push(t);
        }
        out.push((String::from_utf8(name)?, Bundle::new(tensors)));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("lmoe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let params = Bundle::new(vec![
            Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::i32(&[2], vec![7, 8]),
        ]);
        let opt = Bundle::new(vec![Tensor::f32(&[4], vec![0.1, 0.2, 0.3, 0.4])]);
        save(&path, &[("params", &params), ("opt_m", &opt)]).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "params");
        assert_eq!(loaded[0].1.tensors, params.tensors);
        assert_eq!(loaded[1].1.tensors, opt.tensors);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lmoe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }
}
