//! Data-parallel + distributed-optimizer training (paper §2.2.3).
//!
//! Each DP worker is a thread owning its own PJRT runtime and a replica of
//! the parameters.  Per step:
//!   1. every worker runs the `fwd_bwd_*` artifact on its micro-batch,
//!   2. gradients are all-reduced (sum / dp) across the DP group,
//!   3. ZeRO-1: each worker Adam-updates its 1/dp shard of the flat
//!      parameter vector, then shards are all-gathered back.
//!
//! Equivalence to single-worker training on the concatenated batch is an
//! integration test (rust/tests/integration.rs), up to the loss-mean vs
//! grad-mean ordering which is exact here because every micro-batch has
//! the same token count.
//!
//! Fault tolerance: [`run_ddp_resilient`] supervises the worker threads.
//! Worker panics (including injected rank kills) are caught at join and
//! mapped to errors; surviving ranks' collectives fail fast via the
//! poisoned board instead of hanging.  The supervisor then rolls every
//! rank back to the last good checkpoint (params + full ZeRO-1 optimizer
//! state + step counter, CRC-verified with previous-good fallback),
//! rebuilds the communicator, and resumes -- up to `max_restarts` times
//! with exponential backoff.  Because checkpoints capture the *entire*
//! training state and batches are addressed by step index, a recovered
//! run reproduces the uninterrupted run's losses exactly.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::collectives::{Comm, CommCfg, CommFaultStats, CommHandle, CommTraffic};
use crate::coordinator::optimizer::DistributedOptimizer;
use crate::coordinator::{checkpoint, metrics};
use crate::fault::FaultPlan;
use crate::json::Json;
use crate::runtime::Runtime;
use crate::tensor::{Bundle, Tensor};
use crate::trace::{TraceHandle, Track};

pub struct DdpConfig {
    pub artifacts_dir: String,
    pub tag: String,
    pub batch: usize,
    pub seq: usize,
    pub dp: usize,
    pub lr: f32,
    pub steps: usize,
    pub seed: u64,
}

#[derive(Clone, Debug, Default)]
pub struct DdpReport {
    pub losses: Vec<f32>,
    /// final params (from rank 0)
    pub params: Option<Bundle>,
    /// (all-gather bytes, reduce-scatter bytes)
    pub traffic: (u64, u64),
    /// bytes + launches attributed per collective kind (all attempts)
    pub traffic_kinds: CommTraffic,
    pub tokens_per_sec: f64,
    /// checkpoint-rollback recoveries performed (resilient runner only)
    pub recoveries: usize,
    /// human-readable fault / recovery log, in order
    pub fault_events: Vec<String>,
    /// per-rank heartbeats + comm fault counters (resilient runner only)
    pub health: Option<metrics::HealthSnapshot>,
}

/// Batches are produced by a caller-supplied generator so tests can feed
/// identical data to DDP and single-worker baselines.
pub type BatchFn = Arc<dyn Fn(usize, usize) -> (Tensor, Tensor) + Send + Sync>;

/// Join a worker, mapping a panic (rank death) to an error carrying the
/// rank id -- the supervisor treats both failure modes uniformly.
fn join_worker<T>(rank: usize, j: thread::JoinHandle<Result<T>>) -> Result<T> {
    match j.join() {
        Ok(r) => r.with_context(|| format!("rank {rank} failed")),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(anyhow::anyhow!("rank {rank} panicked: {msg}"))
        }
    }
}

pub fn run_ddp(cfg: &DdpConfig, batch_fn: BatchFn) -> Result<DdpReport> {
    let (comm, handles) = Comm::new(cfg.dp);
    let mut joins = Vec::new();
    for (rank, h) in handles.into_iter().enumerate() {
        let cfg_dir = cfg.artifacts_dir.clone();
        let tag = cfg.tag.clone();
        let (b, n, lr, steps, dp) = (cfg.batch, cfg.seq, cfg.lr, cfg.steps, cfg.dp);
        let bf = batch_fn.clone();
        joins.push(thread::spawn(move || -> Result<(Vec<f32>, Option<Bundle>)> {
            worker(rank, dp, h, &cfg_dir, &tag, b, n, lr, steps, bf)
        }));
    }
    let t0 = std::time::Instant::now();
    // Join *all* workers before propagating any failure, so no thread is
    // left detached; then surface the first rank error with its rank id.
    let results: Vec<Result<(Vec<f32>, Option<Bundle>)>> = joins
        .into_iter()
        .enumerate()
        .map(|(rank, j)| join_worker(rank, j))
        .collect();
    let mut losses = Vec::new();
    let mut params = None;
    for (rank, r) in results.into_iter().enumerate() {
        let (l, p) = r?;
        if rank == 0 {
            losses = l;
            params = p;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let (ag, rs, _, _) = comm.traffic();
    Ok(DdpReport {
        losses,
        params,
        traffic: (ag, rs),
        traffic_kinds: comm.traffic_by_kind(),
        tokens_per_sec: (cfg.batch * cfg.seq * cfg.steps) as f64 / dt,
        ..Default::default()
    })
}

#[allow(clippy::too_many_arguments)]
fn worker(
    rank: usize,
    dp: usize,
    comm: CommHandle,
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
    lr: f32,
    steps: usize,
    batch_fn: BatchFn,
) -> Result<(Vec<f32>, Option<Bundle>)> {
    // PJRT wrappers are not Send: each worker builds its own runtime.
    let rt = Runtime::new(artifacts_dir)?;
    let exe = rt.load(&format!("fwd_bwd_{tag}_b{batch}n{seq}"))?;
    let mut params = rt.init_params(tag, 0)?; // same seed => same replica
    let n_params = params.tensors.len();
    let mut opt = DistributedOptimizer::new(params.numel(), dp, rank);

    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        comm.set_step(step);
        // global batch index -> this worker's micro-batch
        let (tokens, targets) = batch_fn(step * dp + rank, seq);
        let out = exe.run_bundled(&[&params], &[&tokens, &targets])?;
        let loss = out[0].item_f32()?;
        let mut grads = Bundle::new(out[2..2 + n_params].to_vec());

        // grad all-reduce (mean) over DP
        let (flat_g, _) = grads.flatten_f32()?;
        let reduced = comm.all_reduce_sum(Tensor::f32(&[flat_g.len()], flat_g))?;
        let mut mean_g = reduced.as_f32()?.to_vec();
        for g in &mut mean_g {
            *g /= dp as f32;
        }
        grads.unflatten_f32(&mean_g)?;

        // loss mean across ranks (for reporting)
        let loss_mean = comm
            .all_reduce_sum(Tensor::scalar_f32(loss))?
            .item_f32()?
            / dp as f32;
        losses.push(loss_mean);

        opt.step_and_allgather(&comm, &mut params, &grads, lr)?;
    }
    let out_params = if rank == 0 { Some(params) } else { None };
    Ok((losses, out_params))
}

// ---------------------------------------------------------------------------
// Resilient DDP: supervised workers + checkpoint rollback.
// ---------------------------------------------------------------------------

/// One rank's model, abstracted from PJRT so the recovery machinery is
/// testable without artifacts: forward+backward on one micro-batch.
pub trait RankModel {
    fn fwd_bwd(
        &mut self,
        params: &Bundle,
        tokens: &Tensor,
        targets: &Tensor,
    ) -> Result<(f32, Bundle)>;
}

/// Per-worker constructor, called *inside* the worker thread (PJRT
/// runtimes are not `Send`).  Returns the rank's model and its initial
/// parameter replica, which must be identical across ranks.
pub type ModelFactory =
    Arc<dyn Fn(usize) -> Result<(Box<dyn RankModel>, Bundle)> + Send + Sync>;

/// The production model: the `fwd_bwd_*` HLO artifact behind [`RankModel`].
struct PjrtModel {
    // keeps the PJRT client alive for as long as the executable runs
    _rt: Runtime,
    exe: std::rc::Rc<crate::runtime::Executable>,
    n_params: usize,
}

impl RankModel for PjrtModel {
    fn fwd_bwd(
        &mut self,
        params: &Bundle,
        tokens: &Tensor,
        targets: &Tensor,
    ) -> Result<(f32, Bundle)> {
        let out = self.exe.run_bundled(&[params], &[tokens, targets])?;
        let loss = out[0].item_f32()?;
        Ok((loss, Bundle::new(out[2..2 + self.n_params].to_vec())))
    }
}

pub fn pjrt_model_factory(
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
) -> ModelFactory {
    let dir = artifacts_dir.to_string();
    let tag = tag.to_string();
    Arc::new(move |_rank| {
        let rt = Runtime::new(&dir)?;
        let exe = rt.load(&format!("fwd_bwd_{tag}_b{batch}n{seq}"))?;
        let params = rt.init_params(&tag, 0)?;
        let n_params = params.tensors.len();
        Ok((
            Box::new(PjrtModel { _rt: rt, exe, n_params }) as Box<dyn RankModel>,
            params,
        ))
    })
}

/// Configuration of the supervised, checkpoint-rollback trainer.
pub struct ResilientCfg {
    pub dp: usize,
    pub batch: usize,
    pub seq: usize,
    pub lr: f32,
    pub steps: usize,
    /// checkpoint cadence in steps (0 disables checkpointing; recovery
    /// then restarts from step 0)
    pub save_every: usize,
    /// how many times a failed attempt may be restarted
    pub max_restarts: usize,
    /// per-collective deadline for the DP group
    pub comm_timeout: Duration,
    /// base supervisor backoff; doubles per consecutive restart
    pub backoff: Duration,
    pub ckpt_path: PathBuf,
    pub faults: Arc<FaultPlan>,
    /// optional tracer: per-rank collective spans plus supervisor
    /// restart/rollback instants land on the same timeline
    pub trace: TraceHandle,
}

/// Full training state captured by a checkpoint: enough to make a
/// recovered run bit-identical to an uninterrupted one.
#[derive(Clone)]
struct ResumeState {
    /// steps already completed (the next step to run)
    start_step: usize,
    params: Bundle,
    /// full padded ZeRO-1 moment vectors (every rank re-shards its slice)
    m: Vec<f32>,
    v: Vec<f32>,
    opt_step: i32,
}

fn resume_from_bundles(mut bundles: Vec<(String, Bundle)>) -> Result<ResumeState> {
    let params = checkpoint::take_bundle(&mut bundles, "params")
        .context("checkpoint has no 'params' bundle")?;
    let m = checkpoint::take_bundle(&mut bundles, "opt_m")
        .context("checkpoint has no 'opt_m' bundle")?;
    let v = checkpoint::take_bundle(&mut bundles, "opt_v")
        .context("checkpoint has no 'opt_v' bundle")?;
    let meta = checkpoint::take_bundle(&mut bundles, "meta")
        .context("checkpoint has no 'meta' bundle")?;
    let meta = meta
        .tensors
        .first()
        .context("empty 'meta' bundle")?
        .as_i32()?
        .to_vec();
    anyhow::ensure!(meta.len() >= 2, "'meta' bundle too short");
    Ok(ResumeState {
        start_step: meta[0] as usize,
        params,
        m: m.tensors.first().context("empty 'opt_m'")?.as_f32()?.to_vec(),
        v: v.tensors.first().context("empty 'opt_v'")?.as_f32()?.to_vec(),
        opt_step: meta[1],
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_resilient(
    rank: usize,
    cfg_dp: usize,
    comm: CommHandle,
    factory: ModelFactory,
    batch_fn: BatchFn,
    seq: usize,
    lr: f32,
    steps: usize,
    save_every: usize,
    ckpt_path: PathBuf,
    faults: Arc<FaultPlan>,
    resume: Option<ResumeState>,
    health: Arc<metrics::HealthBoard>,
    loss_sink: Arc<Mutex<Vec<f32>>>,
) -> Result<Option<Bundle>> {
    let (mut model, init_params) = factory(rank)?;
    let mut params = match &resume {
        Some(r) => r.params.clone(),
        None => init_params,
    };
    let mut opt = DistributedOptimizer::new(params.numel(), cfg_dp, rank);
    let start_step = resume.as_ref().map_or(0, |r| r.start_step);
    if let Some(r) = &resume {
        opt.restore_from_full(&r.m, &r.v, r.opt_step)?;
    }
    for step in start_step..steps {
        comm.set_step(step);
        health.beat(rank);
        let (tokens, targets) = batch_fn(step * cfg_dp + rank, seq);
        let (loss, mut grads) = model.fwd_bwd(&params, &tokens, &targets)?;

        let (flat_g, _) = grads.flatten_f32()?;
        let reduced = comm.all_reduce_sum(Tensor::f32(&[flat_g.len()], flat_g))?;
        let mut mean_g = reduced.as_f32()?.to_vec();
        for g in &mut mean_g {
            *g /= cfg_dp as f32;
        }
        grads.unflatten_f32(&mean_g)?;

        let loss_mean = comm
            .all_reduce_sum(Tensor::scalar_f32(loss))?
            .item_f32()?
            / cfg_dp as f32;
        if rank == 0 {
            loss_sink.lock().unwrap()[step] = loss_mean;
        }

        opt.step_and_allgather(&comm, &mut params, &grads, lr)?;

        if save_every > 0 && (step + 1) % save_every == 0 {
            // Gather every rank's optimizer shard so the checkpoint holds
            // the complete ZeRO-1 state (one packed all-gather: m ++ v).
            let (m, v, opt_step) = opt.shard_state();
            let mut mv = m.to_vec();
            mv.extend_from_slice(v);
            let all = comm.all_gather(Tensor::f32(&[mv.len()], mv))?;
            if rank == 0 {
                let shard = opt.shard;
                let mut m_full = Vec::with_capacity(shard * cfg_dp);
                let mut v_full = Vec::with_capacity(shard * cfg_dp);
                for t in &all {
                    let x = t.as_f32()?;
                    m_full.extend_from_slice(&x[..shard]);
                    v_full.extend_from_slice(&x[shard..]);
                }
                let mb = Bundle::new(vec![Tensor::f32(&[m_full.len()], m_full)]);
                let vb = Bundle::new(vec![Tensor::f32(&[v_full.len()], v_full)]);
                let meta = Bundle::new(vec![Tensor::i32(
                    &[2],
                    vec![(step + 1) as i32, opt_step],
                )]);
                checkpoint::save_rotating(
                    &ckpt_path,
                    &[
                        ("params", &params),
                        ("opt_m", &mb),
                        ("opt_v", &vb),
                        ("meta", &meta),
                    ],
                    &faults,
                )?;
            }
        }
    }
    Ok(if rank == 0 { Some(params) } else { None })
}

/// Supervised DDP: run the ZeRO-1 data-parallel trainer under a supervisor
/// that survives rank death.  Failures (worker panics, collective
/// timeouts, peer failures) abort the attempt; the supervisor rolls back
/// to the last good checkpoint, rebuilds the communicator, and retries
/// with exponential backoff, at most `max_restarts` times.
pub fn run_ddp_resilient(
    cfg: &ResilientCfg,
    factory: ModelFactory,
    batch_fn: BatchFn,
) -> Result<DdpReport> {
    anyhow::ensure!(cfg.dp >= 1, "dp must be >= 1");
    anyhow::ensure!(cfg.steps >= 1, "steps must be >= 1");
    let health = Arc::new(metrics::HealthBoard::new(cfg.dp));
    let loss_sink = Arc::new(Mutex::new(vec![f32::NAN; cfg.steps]));
    let mut comm_stats = CommFaultStats::default();
    let mut traffic_kinds = CommTraffic::default();
    let mut recoveries = 0usize;
    let mut events: Vec<String> = Vec::new();
    let mut resume: Option<ResumeState> = None;
    let mut attempt = 0usize;
    let t0 = Instant::now();
    let sup_track = Track::new("supervisor", 0);
    loop {
        let comm_cfg = CommCfg {
            timeout: cfg.comm_timeout,
            faults: cfg.faults.clone(),
            tracer: cfg.trace.clone(),
        };
        let (comm, handles) = Comm::new_with(cfg.dp, comm_cfg);
        let mut joins = Vec::new();
        for (rank, h) in handles.into_iter().enumerate() {
            let factory = factory.clone();
            let bf = batch_fn.clone();
            let (dp, seq, lr, steps, save_every) =
                (cfg.dp, cfg.seq, cfg.lr, cfg.steps, cfg.save_every);
            let ckpt = cfg.ckpt_path.clone();
            let faults = cfg.faults.clone();
            let res = resume.clone();
            let health = health.clone();
            let sink = loss_sink.clone();
            joins.push(thread::spawn(move || -> Result<Option<Bundle>> {
                worker_resilient(
                    rank, dp, h, factory, bf, seq, lr, steps, save_every, ckpt,
                    faults, res, health, sink,
                )
            }));
        }
        let results: Vec<Result<Option<Bundle>>> = joins
            .into_iter()
            .enumerate()
            .map(|(rank, j)| join_worker(rank, j))
            .collect();
        comm_stats.merge(comm.fault_stats());
        traffic_kinds.merge(comm.traffic_by_kind());

        let first_err = results.iter().position(|r| r.is_err());
        match first_err {
            None => {
                let params = results.into_iter().next().unwrap().unwrap();
                let dt = t0.elapsed().as_secs_f64();
                let (ag, rs, _, _) = comm.traffic();
                let losses = loss_sink.lock().unwrap().clone();
                let report = DdpReport {
                    losses,
                    params,
                    traffic: (ag, rs),
                    traffic_kinds,
                    tokens_per_sec: (cfg.batch * cfg.seq * cfg.steps) as f64 / dt,
                    recoveries,
                    fault_events: events,
                    health: Some(health.snapshot(comm_stats, traffic_kinds)),
                };
                if let Some(t) = cfg.trace.tracer() {
                    if let Some(h) = &report.health {
                        t.with_metrics(|m| crate::coordinator::obs::absorb_health(m, h));
                    }
                }
                return Ok(report);
            }
            Some(rank) => {
                attempt += 1;
                let err = results.into_iter().nth(rank).unwrap().unwrap_err();
                events.push(format!("attempt {attempt}: {err:#}"));
                if cfg.trace.on() {
                    cfg.trace.instant(
                        sup_track.clone(),
                        "fault",
                        "attempt.failed",
                        attempt as u64,
                        vec![
                            ("rank".to_string(), Json::from(rank as u64)),
                            ("err".to_string(), Json::from(format!("{err:#}"))),
                        ],
                    );
                }
                if attempt > cfg.max_restarts {
                    return Err(err.context(format!(
                        "giving up after {} restarts (max_restarts)",
                        cfg.max_restarts
                    )));
                }
                if !cfg.backoff.is_zero() {
                    // exponential backoff, capped at 2^10 x base
                    let exp = (attempt - 1).min(10) as u32;
                    thread::sleep(cfg.backoff * 2u32.pow(exp));
                }
                // Roll back to the last good checkpoint (or step 0 if none
                // was written yet).  `load_with_fallback` transparently
                // uses `<path>.prev` when the newest file is corrupt.
                resume = match checkpoint::load_with_fallback(&cfg.ckpt_path) {
                    Ok((bundles, used_prev)) => {
                        let r = resume_from_bundles(bundles)?;
                        events.push(format!(
                            "recovery {}: rolled back to step {}{}",
                            recoveries + 1,
                            r.start_step,
                            if used_prev { " (previous-good checkpoint)" } else { "" },
                        ));
                        if cfg.trace.on() {
                            cfg.trace.instant(
                                sup_track.clone(),
                                "fault",
                                "recovery.rollback",
                                r.start_step as u64,
                                vec![
                                    (
                                        "recovery".to_string(),
                                        Json::from((recoveries + 1) as u64),
                                    ),
                                    ("used_prev".to_string(), Json::from(used_prev)),
                                ],
                            );
                        }
                        Some(r)
                    }
                    Err(_) => {
                        events.push(format!(
                            "recovery {}: no usable checkpoint, restarting from step 0",
                            recoveries + 1
                        ));
                        if cfg.trace.on() {
                            cfg.trace.instant(
                                sup_track.clone(),
                                "fault",
                                "recovery.restart_scratch",
                                0,
                                vec![(
                                    "recovery".to_string(),
                                    Json::from((recoveries + 1) as u64),
                                )],
                            );
                        }
                        None
                    }
                };
                recoveries += 1;
                health.record_restart();
            }
        }
    }
}

/// Single-worker trainer over the fused `train_step_*` artifact (fwd +
/// bwd + Adam in one HLO launch — one PJRT round-trip per step; see
/// EXPERIMENTS.md §Perf).  Adam state lives inside the artifact I/O.
pub fn run_fused(
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
    lr: f32,
    steps: usize,
    batch_fn: BatchFn,
    log_every: usize,
) -> Result<DdpReport> {
    let rt = Runtime::new(artifacts_dir)?;
    let exe = rt.load(&format!("train_step_{tag}_b{batch}n{seq}"))?;
    let mut params = rt.init_params(tag, 0)?;
    let mut m = params.zeros_like();
    let mut v = params.zeros_like();
    let np = params.tensors.len();
    let lr_t = Tensor::scalar_f32(lr);
    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let (tokens, targets) = batch_fn(step, seq);
        let step_t = Tensor::scalar_i32(step as i32 + 1);
        let out = exe.run_bundled(&[&params, &m, &v],
                                  &[&step_t, &lr_t, &tokens, &targets])?;
        let loss = out[0].item_f32()?;
        losses.push(loss);
        params = Bundle::new(out[2..2 + np].to_vec());
        m = Bundle::new(out[2 + np..2 + 2 * np].to_vec());
        v = Bundle::new(out[2 + 2 * np..2 + 3 * np].to_vec());
        if log_every > 0 && step % log_every == 0 {
            eprintln!("  [{tag}] step {step:5}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok(DdpReport {
        losses,
        params: Some(params),
        traffic: (0, 0),
        tokens_per_sec: (batch * seq * steps) as f64 / dt,
        ..Default::default()
    })
}

/// Single-worker reference trainer over the same fwd_bwd artifact +
/// host-side Adam (the comparison target for the DDP equivalence test and
/// the fallback when dp == 1).
pub fn run_single(
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
    lr: f32,
    steps: usize,
    batch_fn: BatchFn,
    grad_accum: usize,
) -> Result<DdpReport> {
    let rt = Runtime::new(artifacts_dir)?;
    let exe = rt.load(&format!("fwd_bwd_{tag}_b{batch}n{seq}"))?;
    let mut params = rt.init_params(tag, 0)?;
    let n_params = params.tensors.len();
    let mut opt = crate::coordinator::optimizer::LocalAdam::new(params.numel());
    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let mut acc: Option<Bundle> = None;
        let mut loss_acc = 0.0f32;
        for micro in 0..grad_accum {
            let (tokens, targets) = batch_fn(step * grad_accum + micro, seq);
            let out = exe.run_bundled(&[&params], &[&tokens, &targets])?;
            loss_acc += out[0].item_f32()?;
            let grads = Bundle::new(out[2..2 + n_params].to_vec());
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => a.add_assign(&grads)?,
            }
        }
        let mut grads = acc.unwrap();
        grads.scale(1.0 / grad_accum as f32)?;
        losses.push(loss_acc / grad_accum as f32);
        opt.step(&mut params, &grads, lr)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok(DdpReport {
        losses,
        params: Some(params),
        traffic: (0, 0),
        tokens_per_sec: (batch * seq * steps * grad_accum) as f64 / dt,
        ..Default::default()
    })
}
