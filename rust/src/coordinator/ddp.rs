//! Data-parallel + distributed-optimizer training (paper §2.2.3).
//!
//! Each DP worker is a thread owning its own PJRT runtime and a replica of
//! the parameters.  Per step:
//!   1. every worker runs the `fwd_bwd_*` artifact on its micro-batch,
//!   2. gradients are all-reduced (sum / dp) across the DP group,
//!   3. ZeRO-1: each worker Adam-updates its 1/dp shard of the flat
//!      parameter vector, then shards are all-gathered back.
//!
//! Equivalence to single-worker training on the concatenated batch is an
//! integration test (rust/tests/distributed.rs), up to the loss-mean vs
//! grad-mean ordering which is exact here because every micro-batch has
//! the same token count.

use std::sync::Arc;
use std::thread;

use anyhow::Result;

use crate::collectives::{Comm, CommHandle};
use crate::coordinator::optimizer::DistributedOptimizer;
use crate::runtime::Runtime;
use crate::tensor::{Bundle, Tensor};

pub struct DdpConfig {
    pub artifacts_dir: String,
    pub tag: String,
    pub batch: usize,
    pub seq: usize,
    pub dp: usize,
    pub lr: f32,
    pub steps: usize,
    pub seed: u64,
}

#[derive(Clone, Debug, Default)]
pub struct DdpReport {
    pub losses: Vec<f32>,
    /// final params (from rank 0)
    pub params: Option<Bundle>,
    /// (all-gather bytes, reduce-scatter bytes)
    pub traffic: (u64, u64),
    pub tokens_per_sec: f64,
}

/// Batches are produced by a caller-supplied generator so tests can feed
/// identical data to DDP and single-worker baselines.
pub type BatchFn = Arc<dyn Fn(usize, usize) -> (Tensor, Tensor) + Send + Sync>;

pub fn run_ddp(cfg: &DdpConfig, batch_fn: BatchFn) -> Result<DdpReport> {
    let (comm, handles) = Comm::new(cfg.dp);
    let mut joins = Vec::new();
    for (rank, h) in handles.into_iter().enumerate() {
        let cfg_dir = cfg.artifacts_dir.clone();
        let tag = cfg.tag.clone();
        let (b, n, lr, steps, dp) = (cfg.batch, cfg.seq, cfg.lr, cfg.steps, cfg.dp);
        let bf = batch_fn.clone();
        joins.push(thread::spawn(move || -> Result<(Vec<f32>, Option<Bundle>)> {
            worker(rank, dp, h, &cfg_dir, &tag, b, n, lr, steps, bf)
        }));
    }
    let t0 = std::time::Instant::now();
    let mut losses = Vec::new();
    let mut params = None;
    for (rank, j) in joins.into_iter().enumerate() {
        let (l, p) = j.join().expect("worker panicked")?;
        if rank == 0 {
            losses = l;
            params = p;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let (ag, rs, _, _) = comm.traffic();
    Ok(DdpReport {
        losses,
        params,
        traffic: (ag, rs),
        tokens_per_sec: (cfg.batch * cfg.seq * cfg.steps) as f64 / dt,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker(
    rank: usize,
    dp: usize,
    comm: CommHandle,
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
    lr: f32,
    steps: usize,
    batch_fn: BatchFn,
) -> Result<(Vec<f32>, Option<Bundle>)> {
    // PJRT wrappers are not Send: each worker builds its own runtime.
    let rt = Runtime::new(artifacts_dir)?;
    let exe = rt.load(&format!("fwd_bwd_{tag}_b{batch}n{seq}"))?;
    let mut params = rt.init_params(tag, 0)?; // same seed => same replica
    let n_params = params.tensors.len();
    let mut opt = DistributedOptimizer::new(params.numel(), dp, rank);

    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        // global batch index -> this worker's micro-batch
        let (tokens, targets) = batch_fn(step * dp + rank, seq);
        let out = exe.run_bundled(&[&params], &[&tokens, &targets])?;
        let loss = out[0].item_f32()?;
        let mut grads = Bundle::new(out[2..2 + n_params].to_vec());

        // grad all-reduce (mean) over DP
        let (flat_g, _) = grads.flatten_f32()?;
        let reduced = comm.all_reduce_sum(Tensor::f32(&[flat_g.len()], flat_g))?;
        let mut mean_g = reduced.as_f32()?.to_vec();
        for g in &mut mean_g {
            *g /= dp as f32;
        }
        grads.unflatten_f32(&mean_g)?;

        // loss mean across ranks (for reporting)
        let loss_mean = comm
            .all_reduce_sum(Tensor::scalar_f32(loss))?
            .item_f32()?
            / dp as f32;
        losses.push(loss_mean);

        opt.step_and_allgather(&comm, &mut params, &grads, lr)?;
        let _ = step;
    }
    let out_params = if rank == 0 { Some(params) } else { None };
    Ok((losses, out_params))
}

/// Single-worker trainer over the fused `train_step_*` artifact (fwd +
/// bwd + Adam in one HLO launch — one PJRT round-trip per step; see
/// EXPERIMENTS.md §Perf).  Adam state lives inside the artifact I/O.
pub fn run_fused(
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
    lr: f32,
    steps: usize,
    batch_fn: BatchFn,
    log_every: usize,
) -> Result<DdpReport> {
    let rt = Runtime::new(artifacts_dir)?;
    let exe = rt.load(&format!("train_step_{tag}_b{batch}n{seq}"))?;
    let mut params = rt.init_params(tag, 0)?;
    let mut m = params.zeros_like();
    let mut v = params.zeros_like();
    let np = params.tensors.len();
    let lr_t = Tensor::scalar_f32(lr);
    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let (tokens, targets) = batch_fn(step, seq);
        let step_t = Tensor::scalar_i32(step as i32 + 1);
        let out = exe.run_bundled(&[&params, &m, &v],
                                  &[&step_t, &lr_t, &tokens, &targets])?;
        let loss = out[0].item_f32()?;
        losses.push(loss);
        params = Bundle::new(out[2..2 + np].to_vec());
        m = Bundle::new(out[2 + np..2 + 2 * np].to_vec());
        v = Bundle::new(out[2 + 2 * np..2 + 3 * np].to_vec());
        if log_every > 0 && step % log_every == 0 {
            eprintln!("  [{tag}] step {step:5}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok(DdpReport {
        losses,
        params: Some(params),
        traffic: (0, 0),
        tokens_per_sec: (batch * seq * steps) as f64 / dt,
    })
}

/// Single-worker reference trainer over the same fwd_bwd artifact +
/// host-side Adam (the comparison target for the DDP equivalence test and
/// the fallback when dp == 1).
pub fn run_single(
    artifacts_dir: &str,
    tag: &str,
    batch: usize,
    seq: usize,
    lr: f32,
    steps: usize,
    batch_fn: BatchFn,
    grad_accum: usize,
) -> Result<DdpReport> {
    let rt = Runtime::new(artifacts_dir)?;
    let exe = rt.load(&format!("fwd_bwd_{tag}_b{batch}n{seq}"))?;
    let mut params = rt.init_params(tag, 0)?;
    let n_params = params.tensors.len();
    let mut opt = crate::coordinator::optimizer::LocalAdam::new(params.numel());
    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let mut acc: Option<Bundle> = None;
        let mut loss_acc = 0.0f32;
        for micro in 0..grad_accum {
            let (tokens, targets) = batch_fn(step * grad_accum + micro, seq);
            let out = exe.run_bundled(&[&params], &[&tokens, &targets])?;
            loss_acc += out[0].item_f32()?;
            let grads = Bundle::new(out[2..2 + n_params].to_vec());
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => a.add_assign(&grads)?,
            }
        }
        let mut grads = acc.unwrap();
        grads.scale(1.0 / grad_accum as f32)?;
        losses.push(loss_acc / grad_accum as f32);
        opt.step(&mut params, &grads, lr)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok(DdpReport {
        losses,
        params: Some(params),
        traffic: (0, 0),
        tokens_per_sec: (batch * seq * steps * grad_accum) as f64 / dt,
    })
}
