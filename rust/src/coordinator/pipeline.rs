//! Pipeline parallelism (paper §2.2.3, "PP ... operates on complete
//! Linear-MoE blocks").
//!
//! The model is cut into `stages` contiguous layer groups; micro-batches
//! flow through per-layer `block_*`/`embed_*`/`head_*` artifacts with
//! Megatron-style activation recomputation (the `*_bwd` artifacts re-run
//! the forward internally, so only activations / activation-grads cross
//! stage boundaries).
//!
//! Two schedules with a hazard-checked simulator:
//!  - GPipe: all micro-batch forwards, then all backwards (peak activation
//!    memory grows with #micro-batches),
//!  - 1F1B: warmup forwards then alternating fwd/bwd (peak is bounded by
//!    #stages) -- the ablation Table 4 (bottom) exercises.

use anyhow::Result;

use crate::runtime::Runtime;
use crate::tensor::{Bundle, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Fwd(usize),
    Bwd(usize),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    GPipe,
    OneF1B,
}

/// Per-stage op sequence for `m` micro-batches.
pub fn schedule_ops(kind: Schedule, stage: usize, stages: usize, m: usize) -> Vec<Op> {
    match kind {
        Schedule::GPipe => (0..m)
            .map(Op::Fwd)
            .chain((0..m).map(Op::Bwd))
            .collect(),
        Schedule::OneF1B => {
            // warmup = min(stages - stage, m) forwards, then 1F1B, then
            // drain remaining backwards.
            let warmup = (stages - stage).min(m);
            let mut ops = Vec::with_capacity(2 * m);
            let mut f = 0usize;
            let mut b = 0usize;
            for _ in 0..warmup {
                ops.push(Op::Fwd(f));
                f += 1;
            }
            while f < m {
                ops.push(Op::Bwd(b));
                b += 1;
                ops.push(Op::Fwd(f));
                f += 1;
            }
            while b < m {
                ops.push(Op::Bwd(b));
                b += 1;
            }
            ops
        }
    }
}

/// Validate a full-pipeline schedule against data hazards and report the
/// peak number of in-flight activations per stage (the memory proxy).
/// Fwd(mb)@s needs Fwd(mb)@(s-1) done; Bwd(mb)@s needs Bwd(mb)@(s+1) and
/// Fwd(mb)@s done.
pub fn simulate(kind: Schedule, stages: usize, m: usize) -> Result<SimReport> {
    let ops: Vec<Vec<Op>> = (0..stages)
        .map(|s| schedule_ops(kind, s, stages, m))
        .collect();
    let mut idx = vec![0usize; stages];
    let mut fwd_done = vec![vec![false; m]; stages];
    let mut bwd_done = vec![vec![false; m]; stages];
    let mut live = vec![0usize; stages];
    let mut peak = vec![0usize; stages];
    let mut ticks = 0usize;
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for s in 0..stages {
            if idx[s] >= ops[s].len() {
                continue;
            }
            all_done = false;
            let op = ops[s][idx[s]];
            let ready = match op {
                Op::Fwd(mb) => s == 0 || fwd_done[s - 1][mb],
                Op::Bwd(mb) => {
                    fwd_done[s][mb] && (s == stages - 1 || bwd_done[s + 1][mb])
                }
            };
            if ready {
                match op {
                    Op::Fwd(mb) => {
                        fwd_done[s][mb] = true;
                        live[s] += 1;
                        peak[s] = peak[s].max(live[s]);
                    }
                    Op::Bwd(mb) => {
                        bwd_done[s][mb] = true;
                        live[s] -= 1;
                    }
                }
                idx[s] += 1;
                progressed = true;
            }
        }
        if all_done {
            break;
        }
        anyhow::ensure!(progressed, "schedule deadlocked (hazard)");
        ticks += 1;
    }
    Ok(SimReport { peak_live: peak, ticks })
}

#[derive(Clone, Debug)]
pub struct SimReport {
    /// peak in-flight fwd activations per stage
    pub peak_live: Vec<usize>,
    pub ticks: usize,
}

// ---------------------------------------------------------------------------
// Single-process pipeline executor (correctness path): runs all stages in
// one thread, honoring the schedule order, over the per-layer artifacts.
// The multi-worker wall-clock bench drives the same artifacts from
// separate stage threads (see benches/table4_parallel.rs).
// ---------------------------------------------------------------------------

pub struct PipelineModel {
    pub tag: String,
    /// layer kinds, e.g. "LLLN"
    pub layout: Vec<char>,
    pub mb: usize,
    pub seq: usize,
    embed: std::rc::Rc<crate::runtime::Executable>,
    embed_bwd: std::rc::Rc<crate::runtime::Executable>,
    head_bwd: std::rc::Rc<crate::runtime::Executable>,
    block_fwd_l: std::rc::Rc<crate::runtime::Executable>,
    block_bwd_l: std::rc::Rc<crate::runtime::Executable>,
    block_fwd_n: Option<std::rc::Rc<crate::runtime::Executable>>,
    block_bwd_n: Option<std::rc::Rc<crate::runtime::Executable>>,
}

impl PipelineModel {
    pub fn new(rt: &Runtime, tag: &str, layout: &str, mb: usize, seq: usize) -> Result<Self> {
        let sfx = format!("{tag}_mb{mb}n{seq}");
        let attn_tag = tag.rsplit_once('_').map(|(p, _)| format!("{p}_attn"));
        let need_n = layout.contains('N');
        Ok(PipelineModel {
            tag: tag.to_string(),
            layout: layout.chars().collect(),
            mb,
            seq,
            embed: rt.load(&format!("embed_{sfx}"))?,
            embed_bwd: rt.load(&format!("embed_bwd_{sfx}"))?,
            head_bwd: rt.load(&format!("head_bwd_{sfx}"))?,
            block_fwd_l: rt.load(&format!("block_L_{sfx}"))?,
            block_bwd_l: rt.load(&format!("block_L_bwd_{sfx}"))?,
            block_fwd_n: if need_n {
                Some(rt.load(&format!(
                    "block_N_{}_mb{mb}n{seq}",
                    attn_tag.clone().unwrap()
                ))?)
            } else {
                None
            },
            block_bwd_n: if need_n {
                Some(rt.load(&format!(
                    "block_N_bwd_{}_mb{mb}n{seq}",
                    attn_tag.unwrap()
                ))?)
            } else {
                None
            },
        })
    }

    /// Full fwd+bwd for one micro-batch, composed from stage artifacts.
    /// `layer_params[i]` is the Bundle of layer i (manifest order);
    /// `embed`/`final_norm` are the tied embedding and final norm.
    /// Returns (ce, grads per layer, g_embed, g_final_norm).
    #[allow(clippy::type_complexity)]
    pub fn fwd_bwd(
        &self,
        embed: &Tensor,
        final_norm: &Tensor,
        layer_params: &[Bundle],
        tokens: &Tensor,
        targets: &Tensor,
    ) -> Result<(f32, Vec<Bundle>, Tensor, Tensor)> {
        // forward: keep stage inputs (activation recomputation keeps only
        // these (mb, n, d) tensors live -- the Megatron trade).
        let mut acts: Vec<Tensor> = Vec::with_capacity(self.layout.len() + 1);
        let x0 = self.embed.run(&[embed, tokens])?.remove(0);
        acts.push(x0);
        for (i, &ch) in self.layout.iter().enumerate() {
            let exe = if ch == 'L' {
                &self.block_fwd_l
            } else {
                self.block_fwd_n.as_ref().expect("no N artifacts")
            };
            let out = exe.run_bundled(&[&layer_params[i]], &[acts.last().unwrap()])?;
            acts.push(out.into_iter().next().unwrap());
        }
        // head bwd (computes loss + gx + embed/final grads)
        let out = self
            .head_bwd
            .run(&[final_norm, embed, acts.last().unwrap(), targets])?;
        let (g_fn, mut g_embed, mut gx, ce) = (
            out[0].clone(),
            out[1].clone(),
            out[2].clone(),
            out[3].item_f32()?,
        );
        // backward through blocks in reverse (recompute inside artifact)
        let mut layer_grads: Vec<Option<Bundle>> = vec![None; self.layout.len()];
        for (i, &ch) in self.layout.iter().enumerate().rev() {
            let exe = if ch == 'L' {
                &self.block_bwd_l
            } else {
                self.block_bwd_n.as_ref().unwrap()
            };
            let mut out = exe.run_bundled(&[&layer_params[i]], &[&acts[i], &gx])?;
            gx = out.pop().unwrap(); // last result = gx
            layer_grads[i] = Some(Bundle::new(out));
        }
        // embedding backward (token gather) + tie with head grad
        let g_emb_tok = self.embed_bwd.run(&[tokens, &gx])?.remove(0);
        g_embed.add_assign(&g_emb_tok)?;
        Ok((
            ce,
            layer_grads.into_iter().map(Option::unwrap).collect(),
            g_embed,
            g_fn,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::check;

    #[test]
    fn gpipe_schedule_valid_and_peak_is_m() {
        let r = simulate(Schedule::GPipe, 4, 8).unwrap();
        assert_eq!(r.peak_live, vec![8, 8, 8, 8]);
    }

    #[test]
    fn one_f1b_bounds_peak_by_stage_depth() {
        let r = simulate(Schedule::OneF1B, 4, 8).unwrap();
        // 1F1B: stage s holds at most (stages - s) activations
        assert_eq!(r.peak_live, vec![4, 3, 2, 1]);
    }

    #[test]
    fn schedules_valid_for_many_shapes() {
        check("pipeline_schedules_valid", 48, |rng| {
            let stages = 1 + rng.below(8);
            let m = 1 + rng.below(12);
            for kind in [Schedule::GPipe, Schedule::OneF1B] {
                let r = simulate(kind, stages, m).unwrap();
                // every stage must end with zero live activations
                assert!(r.peak_live.iter().all(|&p| p >= 1));
                if stages > 1 && m >= stages {
                    let g = simulate(Schedule::GPipe, stages, m).unwrap();
                    let f = simulate(Schedule::OneF1B, stages, m).unwrap();
                    assert!(
                        f.peak_live[0] <= g.peak_live[0],
                        "1F1B peak must not exceed GPipe"
                    );
                }
            }
        });
    }
}
