//! Observability glue: adapters that fold the pre-existing one-off stat
//! structs ([`CommTraffic`], [`CommFaultStats`], [`HealthSnapshot`],
//! [`ServeOutcomes`], [`ServeReport`], [`EpStats`]) into the unified
//! [`MetricsRegistry`], plus span-derived re-computations of the two
//! headline ratios — serving occupancy and EP compute/comm overlap — so
//! tests can assert that the trace and the hand-maintained counters
//! agree.
//!
//! The adapters do not replace the source structs (tests and reports
//! still use them directly); they give every number a stable registry
//! name so one `metrics` JSON blob carries the whole story.

use crate::collectives::{CommFaultStats, CommTraffic};
use crate::coordinator::metrics::{HealthSnapshot, ServeOutcomes};
use crate::coordinator::moe_ep::EpStats;
use crate::json::Json;
use crate::serve::ServeReport;
use crate::trace::{Event, Kind, MetricsRegistry};

pub fn absorb_traffic(m: &mut MetricsRegistry, t: &CommTraffic) {
    m.inc("comm.all_gather.bytes", t.all_gather_bytes);
    m.inc("comm.all_gather.ops", t.all_gather_ops);
    m.inc("comm.reduce_scatter.bytes", t.reduce_scatter_bytes);
    m.inc("comm.reduce_scatter.ops", t.reduce_scatter_ops);
    m.inc("comm.ring.bytes", t.ring_bytes);
    m.inc("comm.ring.ops", t.ring_ops);
    m.inc("comm.all_to_all.bytes", t.all_to_all_bytes);
    m.inc("comm.all_to_all.ops", t.all_to_all_ops);
    m.inc("comm.total.bytes", t.total_bytes());
}

pub fn absorb_comm_faults(m: &mut MetricsRegistry, f: &CommFaultStats) {
    m.inc("fault.timeouts", f.timeouts);
    m.inc("fault.peer_failures", f.peer_failures);
    m.inc("fault.injected_kills", f.injected_kills);
    m.inc("fault.injected_delays", f.injected_delays);
    m.inc("fault.dropped_ring", f.dropped_ring);
}

pub fn absorb_health(m: &mut MetricsRegistry, h: &HealthSnapshot) {
    for (rank, beats) in h.heartbeats.iter().enumerate() {
        m.inc(&format!("health.heartbeats.rank{rank}"), *beats);
    }
    m.inc("health.restarts", h.restarts);
    absorb_comm_faults(m, &h.comm);
    absorb_traffic(m, &h.traffic);
}

pub fn absorb_outcomes(m: &mut MetricsRegistry, o: &ServeOutcomes) {
    m.inc("serve.outcome.finished", o.finished);
    m.inc("serve.outcome.expired", o.expired);
    m.inc("serve.outcome.shed", o.shed);
    m.inc("serve.outcome.failed", o.failed);
    m.inc("serve.outcome.recovered", o.recovered);
}

pub fn absorb_serve_report(m: &mut MetricsRegistry, r: &ServeReport) {
    m.inc("serve.ticks", r.ticks);
    m.inc("serve.steps", r.steps);
    m.inc("serve.active_lane_steps", r.active_lane_steps);
    m.inc("serve.tokens_out", r.tokens_out);
    m.inc("serve.swaps", r.swaps);
    m.inc("serve.swap_bytes", r.swap_bytes);
    m.inc("serve.state_reallocs", r.state_reallocs);
    m.inc("serve.rejected", r.rejected);
    m.inc("serve.faults_injected", r.faults_injected);
    m.inc("serve.stalled_ticks", r.stalled_ticks);
    m.inc("serve.crc_failures", r.crc_failures);
    m.inc("serve.corruptions_injected", r.corruptions_injected);
    m.gauge("serve.occupancy", r.occupancy());
    m.gauge("serve.tokens_per_sec", r.tokens_per_sec());
    absorb_outcomes(m, &r.outcomes);
}

pub fn absorb_ep_stats(m: &mut MetricsRegistry, rank: usize, s: &EpStats) {
    let p = format!("ep.rank{rank}");
    m.inc(&format!("{p}.rounds"), s.rounds as u64);
    m.inc(&format!("{p}.launches"), s.launches as u64);
    m.inc(&format!("{p}.sent_rows"), s.sent_rows as u64);
    m.inc(&format!("{p}.recv_rows"), s.recv_rows as u64);
    m.inc(&format!("{p}.dropped_rows"), s.dropped_rows as u64);
    m.inc(&format!("{p}.payload_bytes"), s.payload_bytes);
    m.gauge(&format!("{p}.comm_wait_us"), s.comm_wait.as_secs_f64() * 1e6);
    m.gauge(&format!("{p}.compute_us"), s.compute.as_secs_f64() * 1e6);
    m.gauge(&format!("{p}.overlap_frac"), s.overlap_frac());
}

fn arg<'a>(ev: &'a Event, key: &str) -> Option<&'a Json> {
    ev.args.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

/// Serving occupancy re-derived from the trace: the mean of the
/// `active` arg over all `engine.step` spans. `engine.step` is emitted
/// once per decoder step that ran a batch, so this must equal
/// [`ServeReport::occupancy`] *exactly* (both are ratios of the same
/// integer tick-domain counters).
pub fn span_occupancy(events: &[Event]) -> Option<f64> {
    let mut steps = 0u64;
    let mut active = 0u64;
    for ev in events {
        if ev.name == "engine.step" && matches!(ev.kind, Kind::Span { .. }) {
            steps += 1;
            active += arg(ev, "active")?.as_f64()? as u64;
        }
    }
    if steps == 0 {
        None
    } else {
        Some(active as f64 / steps as f64)
    }
}

/// EP overlap fraction re-derived from the trace: wall time of
/// `ep.expert` spans whose `overlapped` arg is true over the wall time
/// of all `ep.expert` spans. Each span carries the same measured
/// duration that `forward_ep` adds into `EpStats.compute`, so this
/// agrees with [`EpStats::overlap_frac`] up to f64 summation order.
pub fn span_overlap_frac(events: &[Event]) -> Option<f64> {
    let mut total = 0.0f64;
    let mut overlapped = 0.0f64;
    let mut seen = false;
    for ev in events {
        if ev.name == "ep.expert" && matches!(ev.kind, Kind::Span { .. }) {
            seen = true;
            let dur = ev.wall_dur_us?;
            total += dur;
            if arg(ev, "overlapped") == Some(&Json::Bool(true)) {
                overlapped += dur;
            }
        }
    }
    if !seen || total == 0.0 {
        if seen {
            return Some(0.0);
        }
        return None;
    }
    Some(overlapped / total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Track;

    fn step(tick: u64, active: u64) -> Event {
        Event {
            track: Track::new("engine", 0),
            cat: "serve",
            name: "engine.step".to_string(),
            tick,
            kind: Kind::Span { dur_ticks: 1 },
            args: vec![("active".to_string(), Json::from(active))],
            wall_us: None,
            wall_dur_us: None,
        }
    }

    fn expert(round: u64, overlapped: bool, dur_us: f64) -> Event {
        Event {
            track: Track::new("ep", 0),
            cat: "ep",
            name: "ep.expert".to_string(),
            tick: round,
            kind: Kind::Span { dur_ticks: 0 },
            args: vec![("overlapped".to_string(), Json::Bool(overlapped))],
            wall_us: Some(0.0),
            wall_dur_us: Some(dur_us),
        }
    }

    #[test]
    fn occupancy_from_spans() {
        assert_eq!(span_occupancy(&[]), None);
        let evs = vec![step(0, 4), step(1, 2), step(2, 3)];
        assert_eq!(span_occupancy(&evs), Some(3.0));
    }

    #[test]
    fn overlap_from_spans() {
        assert_eq!(span_overlap_frac(&[]), None);
        let evs = vec![
            expert(0, false, 10.0),
            expert(1, true, 20.0),
            expert(2, true, 10.0),
        ];
        let f = span_overlap_frac(&evs).unwrap();
        assert!((f - 0.75).abs() < 1e-12, "got {f}");
        // all-zero durations: defined as 0.0, not NaN
        assert_eq!(span_overlap_frac(&[expert(0, true, 0.0)]), Some(0.0));
    }

    #[test]
    fn absorb_adapters_populate_registry() {
        let mut m = MetricsRegistry::default();
        let t = CommTraffic { all_gather_bytes: 8, all_gather_ops: 1, ..Default::default() };
        absorb_traffic(&mut m, &t);
        assert_eq!(m.counter("comm.all_gather.bytes"), 8);
        assert_eq!(m.counter("comm.total.bytes"), 8);

        let o = ServeOutcomes { finished: 3, shed: 1, ..Default::default() };
        absorb_outcomes(&mut m, &o);
        assert_eq!(m.counter("serve.outcome.finished"), 3);
        assert_eq!(m.counter("serve.outcome.shed"), 1);

        let s = EpStats { rounds: 2, payload_bytes: 64, ..Default::default() };
        absorb_ep_stats(&mut m, 1, &s);
        assert_eq!(m.counter("ep.rank1.rounds"), 2);
        assert_eq!(m.counter("ep.rank1.payload_bytes"), 64);
        assert_eq!(m.gauge_value("ep.rank1.overlap_frac"), Some(0.0));

        crate::json::parse(&m.to_json().to_string()).expect("registry json parses");
    }
}
