//! LASP sequence parallelism (paper §2.2.1 + App. A.3) and the hybrid-model
//! SP strategy (paper §2.2.2).
//!
//! Kernel-level executors, exactly the paper's Alg. 1/2: the sequence is
//! split into T chunks over T SP ranks; each rank computes its memory-state
//! contribution `M_t = K_t^T V_t` (with the instance's decay) via the
//! `sp_state_*` artifact, states are exchanged, each rank folds the strict
//! prefix `M_{1:t-1}` and computes its output via `sp_output_*`.
//!
//! Two communication modes:
//!  - `Lasp2` (paper's LASP-2): one **AllGather** of the (Dk, Dv) states;
//!    every rank folds the prefix locally.  Single collective, O(T d^2)
//!    volume independent of sequence length.
//!  - `Lasp1` ring: rank t receives the folded prefix M_{1:t-1} from rank
//!    t-1, uses it, folds its own contribution, sends M_{1:t} to t+1 --
//!    the point-to-point pattern of LASP-1 (sequential chain).
//!
//! For the attention ('N') layers of hybrid models, `attn_sp` all-gathers
//! K/V across ranks and computes local-Q attention (the Llama3-style
//! strategy the paper adopts): communication is O(N d) and grows with
//! sequence length -- the contrast the hybrid-SP bench measures.

use anyhow::Result;
use std::sync::Arc;

use crate::collectives::CommHandle;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpMode {
    Lasp1Ring,
    Lasp2AllGather,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateKind {
    None,
    Scalar,
    Vector,
}

impl GateKind {
    pub fn tag(&self) -> &'static str {
        match self {
            GateKind::None => "none",
            GateKind::Scalar => "scalar",
            GateKind::Vector => "vector",
        }
    }
}

/// Fold `contrib` into `prefix` under the chunk's total log-decay:
/// M' = exp(log_decay)[:, None] * M + contrib.   Shapes:
/// prefix/contrib (B, H, Dk, Dv), log_decay (B, H, Dk).
pub fn fold_state(prefix: &mut Tensor, contrib: &Tensor, log_decay: &Tensor) -> Result<()> {
    let (dk, dv) = {
        let s = &prefix.shape;
        (s[s.len() - 2], s[s.len() - 1])
    };
    let ld = log_decay.as_f32()?.to_vec();
    let c = contrib.as_f32()?.to_vec();
    let p = prefix.as_f32_mut()?;
    // iterate (bh, dk, dv)
    let bh = p.len() / (dk * dv);
    for b in 0..bh {
        for i in 0..dk {
            let decay = ld[b * dk + i].exp();
            let row = b * dk * dv + i * dv;
            for j in 0..dv {
                p[row + j] = decay * p[row + j] + c[row + j];
            }
        }
    }
    Ok(())
}

/// Per-rank LASP execution for one (already chunk-split) LSM layer input.
/// `q/k/v`: this rank's chunk (B, H, C, D).  `gates`: None / (B,H,C) /
/// (B,H,C,Dk) according to `kind`.  Returns this rank's output chunk.
pub struct SpExecutor {
    pub kind: GateKind,
    state_exe: std::rc::Rc<crate::runtime::Executable>,
    out_exe: std::rc::Rc<crate::runtime::Executable>,
}

impl SpExecutor {
    pub fn new(rt: &Runtime, kind: GateKind) -> Result<Self> {
        Ok(SpExecutor {
            kind,
            state_exe: rt.load(&format!("sp_state_{}", kind.tag()))?,
            out_exe: rt.load(&format!("sp_output_{}", kind.tag()))?,
        })
    }

    fn state(&self, k: &Tensor, v: &Tensor, gates: Option<&Tensor>) -> Result<(Tensor, Tensor)> {
        let out = match (self.kind, gates) {
            (GateKind::None, _) => self.state_exe.run(&[k, v])?,
            (_, Some(g)) => self.state_exe.run(&[k, v, g])?,
            _ => anyhow::bail!("gate kind {:?} requires gates", self.kind),
        };
        Ok((out[0].clone(), out[1].clone()))
    }

    fn output(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        gates: Option<&Tensor>,
        m_prefix: &Tensor,
    ) -> Result<Tensor> {
        let out = match (self.kind, gates) {
            (GateKind::None, _) => self.out_exe.run(&[q, k, v, m_prefix])?,
            (_, Some(g)) => self.out_exe.run(&[q, k, v, g, m_prefix])?,
            _ => anyhow::bail!("gate kind {:?} requires gates", self.kind),
        };
        Ok(out[0].clone())
    }

    /// One LASP layer pass on this SP rank.  (Paper Alg. 2; the masked
    /// variant -- intra-chunk causality is handled inside `sp_output`.)
    pub fn run(
        &self,
        comm: &CommHandle,
        mode: SpMode,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        gates: Option<&Tensor>,
    ) -> Result<Tensor> {
        let (mc, ld) = self.state(k, v, gates)?;
        let state_shape = mc.shape.clone();
        let m_prefix = match mode {
            SpMode::Lasp2AllGather => {
                // LASP-2: one AllGather of (contrib, log_decay); every rank
                // folds the strict prefix locally.
                let packed = pack_state(&mc, &ld)?;
                let all = comm.all_gather(packed)?;
                let mut prefix = Tensor::zeros(&state_shape);
                for t in all.iter().take(comm.rank) {
                    let (c, d) = unpack_state(t, &state_shape)?;
                    fold_state(&mut prefix, &c, &d)?;
                }
                prefix
            }
            SpMode::Lasp1Ring => {
                // LASP-1: sequential ring chain.  Rank 0 starts from zero;
                // rank t receives M_{1:t-1}+flag from t-1.  The ring wraps,
                // so the last rank's send is drained by rank 0 (discarded).
                let zero = Tensor::zeros(&state_shape);
                let prefix = if comm.rank == 0 {
                    zero.clone()
                } else {
                    // blocking receive of the folded prefix from rank-1
                    comm.ring_recv()?
                };
                // fold our contribution and pass along
                let mut next = prefix.clone();
                fold_state(&mut next, &mc, &ld)?;
                comm.ring_send(next)?;
                if comm.rank == 0 {
                    // drain the wrap-around message from the last rank
                    let _ = comm.ring_recv()?;
                }
                prefix
            }
        };
        self.output(q, k, v, gates, &m_prefix)
    }
}

/// Pack (contrib, log_decay) into one tensor for a single collective
/// (LASP-2 sends exactly one message per rank).
pub fn pack_state(mc: &Tensor, ld: &Tensor) -> Result<Tensor> {
    let mut data = mc.as_f32()?.to_vec();
    data.extend_from_slice(ld.as_f32()?);
    Ok(Tensor::f32(&[data.len()], data))
}

pub fn unpack_state(packed: &Tensor, state_shape: &[usize]) -> Result<(Tensor, Tensor)> {
    let n: usize = state_shape.iter().product();
    let v = packed.as_f32()?;
    let mut ld_shape = state_shape.to_vec();
    ld_shape.pop();
    let ld_n: usize = ld_shape.iter().product();
    anyhow::ensure!(
        v.len() == n + ld_n,
        "packed state has {} elems, expected {} (state) + {} (log-decay) \
         for state shape {state_shape:?}",
        v.len(),
        n,
        ld_n
    );
    Ok((
        Tensor::f32(state_shape, v[..n].to_vec()),
        Tensor::f32(&ld_shape, v[n..].to_vec()),
    ))
}

/// Hybrid-SP attention layer (paper §2.2.2): all-gather K/V over the SP
/// group, compute attention for the local Q chunk with the correct global
/// offset.  `t` = SP world size baked into the artifact name.
pub struct AttnSpExecutor {
    exe: std::rc::Rc<crate::runtime::Executable>,
    chunk: usize,
}

impl AttnSpExecutor {
    pub fn new(rt: &Runtime, sp_world: usize) -> Result<Self> {
        let exe = rt.load(&format!("attn_sp_t{sp_world}"))?;
        let chunk = exe.spec.meta_usize("chunk").unwrap_or(0);
        Ok(AttnSpExecutor { exe, chunk })
    }

    pub fn run(
        &self,
        comm: &CommHandle,
        q_local: &Tensor,
        k_local: &Tensor,
        v_local: &Tensor,
    ) -> Result<Tensor> {
        // AllGather K and V along the sequence axis (rank order).
        let ks = comm.all_gather(k_local.clone())?;
        let vs = comm.all_gather(v_local.clone())?;
        let k_full = concat_seq(&ks)?;
        let v_full = concat_seq(&vs)?;
        let pos0 = Tensor::scalar_i32((comm.rank * self.chunk) as i32);
        Ok(self.exe.run(&[q_local, &k_full, &v_full, &pos0])?[0].clone())
    }
}

/// Concatenate (B, H, C, D) chunks along the sequence axis.
pub fn concat_seq(parts: &[Arc<Tensor>]) -> Result<Tensor> {
    anyhow::ensure!(!parts.is_empty());
    let s = &parts[0].shape;
    anyhow::ensure!(s.len() == 4, "expected (B,H,C,D)");
    let (b, h, c, d) = (s[0], s[1], s[2], s[3]);
    let t = parts.len();
    let mut out = vec![0f32; b * h * c * t * d];
    for (ti, part) in parts.iter().enumerate() {
        let src = part.as_f32()?;
        for bi in 0..b * h {
            for ci in 0..c {
                let dst_row = (bi * (c * t) + ti * c + ci) * d;
                let src_row = (bi * c + ci) * d;
                out[dst_row..dst_row + d]
                    .copy_from_slice(&src[src_row..src_row + d]);
            }
        }
    }
    Ok(Tensor::f32(&[b, h, c * t, d], out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_state_applies_decay() {
        let mut prefix = Tensor::f32(&[1, 1, 2, 2], vec![1., 1., 1., 1.]);
        let contrib = Tensor::f32(&[1, 1, 2, 2], vec![0.5; 4]);
        let ld = Tensor::f32(&[1, 1, 2], vec![0.0, (0.5f32).ln()]);
        fold_state(&mut prefix, &contrib, &ld).unwrap();
        let got = prefix.as_f32().unwrap();
        assert!((got[0] - 1.5).abs() < 1e-6); // decay 1.0
        assert!((got[2] - 1.0).abs() < 1e-6); // decay 0.5
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mc = Tensor::f32(&[1, 1, 2, 3], (0..6).map(|x| x as f32).collect());
        let ld = Tensor::f32(&[1, 1, 2], vec![-0.1, -0.2]);
        let packed = pack_state(&mc, &ld).unwrap();
        let (mc2, ld2) = unpack_state(&packed, &[1, 1, 2, 3]).unwrap();
        assert_eq!(mc, mc2);
        assert_eq!(ld, ld2);
    }

    #[test]
    fn concat_seq_layout() {
        let a = Arc::new(Tensor::f32(&[1, 1, 2, 2], vec![1., 2., 3., 4.]));
        let b = Arc::new(Tensor::f32(&[1, 1, 2, 2], vec![5., 6., 7., 8.]));
        let c = concat_seq(&[a, b]).unwrap();
        assert_eq!(c.shape, vec![1, 1, 4, 2]);
        assert_eq!(c.as_f32().unwrap(), &[1., 2., 3., 4., 5., 6., 7., 8.]);
    }
}
