//! The Linear-MoE Training subsystem (paper §2.2): everything the paper
//! attributes to the training system lives here, composed from the
//! substrates (collectives, topology, runtime) below it.
//!
//!  - [`optimizer`]: Adam + ZeRO-1 distributed optimizer
//!  - [`ddp`]: data-parallel training over worker threads
//!  - [`sp`]: LASP-1/LASP-2 sequence parallelism + hybrid-model SP
//!  - [`pipeline`]: GPipe / 1F1B schedules + per-layer stage execution
//!  - [`moe_ep`]: expert-parallel token dispatch + MoE exec strategies
//!  - [`checkpoint`]: parameter/optimizer-state save & load
//!  - [`metrics`]: throughput / loss-curve recording
//!  - [`obs`]: adapters folding the one-off stat structs into the
//!    unified [`crate::trace`] registry + span-derived cross-checks

pub mod checkpoint;
pub mod ddp;
pub mod metrics;
pub mod moe_ep;
pub mod obs;
pub mod optimizer;
pub mod pipeline;
pub mod sp;
