//! Training metrics: loss-curve recording (Fig. 6/7), throughput meters
//! (Table 3 / Fig. 4), simple CSV output for plotting, and the per-rank
//! health board the fault-tolerant trainer reports recoveries through.

use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::collectives::{CommFaultStats, CommTraffic};

// ---------------------------------------------------------------------------
// Health board: per-rank heartbeats + recovery counters, shared between the
// resilient supervisor and its workers so liveness is observable while a
// run is in flight (and reportable afterwards).
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct HealthBoard {
    beats: Vec<AtomicU64>,
    pub restarts: AtomicU64,
}

impl HealthBoard {
    pub fn new(world: usize) -> Self {
        HealthBoard {
            beats: (0..world).map(|_| AtomicU64::new(0)).collect(),
            restarts: AtomicU64::new(0),
        }
    }

    /// One heartbeat from `rank` (called at the top of every training
    /// step; a rank whose count stalls is hung or dead).
    pub fn beat(&self, rank: usize) {
        self.beats[rank].fetch_add(1, Ordering::Relaxed);
    }

    pub fn heartbeats(&self, rank: usize) -> u64 {
        self.beats[rank].load(Ordering::Relaxed)
    }

    pub fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Freeze the counters (plus the communicator's fault counters and
    /// per-kind traffic attribution) into a plain value for `DdpReport`.
    pub fn snapshot(&self, comm: CommFaultStats, traffic: CommTraffic) -> HealthSnapshot {
        HealthSnapshot {
            heartbeats: self.beats.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            restarts: self.restarts.load(Ordering::Relaxed),
            comm,
            traffic,
        }
    }
}

/// Plain-value snapshot of `HealthBoard` + comm fault counters + per-kind
/// traffic (all_gather / reduce_scatter / ring / all_to_all).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthSnapshot {
    /// steps started per rank (across all attempts, replays included)
    pub heartbeats: Vec<u64>,
    pub restarts: u64,
    pub comm: CommFaultStats,
    pub traffic: CommTraffic,
}

/// Per-outcome request counts for a serving run: how many requests
/// finished, expired past their deadline, were shed at admission, or
/// failed after exhausting their retry budget.  `recovered` counts the
/// subset of `finished` that needed at least one fault-recovery replay.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeOutcomes {
    pub finished: u64,
    pub expired: u64,
    pub shed: u64,
    pub failed: u64,
    /// finished after >= 1 re-prefill replay (subset of `finished`)
    pub recovered: u64,
}

impl ServeOutcomes {
    /// Requests accounted for (every submitted request lands in exactly
    /// one bucket; `recovered` overlaps `finished` and is not added).
    pub fn total(&self) -> u64 {
        self.finished + self.expired + self.shed + self.failed
    }

    /// A fully clean run: nothing expired, shed, or failed.
    pub fn all_finished(&self) -> bool {
        self.expired == 0 && self.shed == 0 && self.failed == 0
    }
}

/// Order statistics over a set of per-request serving measurements
/// (queue wait, TTFT, tokens) -- what the serve CLI and bench report.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// finite samples summarized (NaN/inf inputs are dropped, not counted)
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Order statistics over the *finite* entries of `xs`. Non-finite
    /// samples are discarded rather than panicking (the old
    /// `partial_cmp(..).unwrap()` aborted on any NaN) or poisoning the
    /// percentiles; an all-NaN input yields the zero `Summary`.
    pub fn of(xs: &[f64]) -> Summary {
        let mut sorted: Vec<f64> = xs.iter().copied().filter(|v| v.is_finite()).collect();
        if sorted.is_empty() {
            return Summary::default();
        }
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let pct = |q: f64| sorted[(((n as f64) * q) as usize).min(n - 1)];
        Summary {
            n,
            mean: sorted.iter().sum::<f64>() / n as f64,
            min: sorted[0],
            p50: sorted[n / 2],
            p95: pct(0.95),
            p99: pct(0.99),
            max: sorted[n - 1],
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub label: String,
    pub steps: Vec<usize>,
    pub losses: Vec<f32>,
}

impl LossCurve {
    pub fn new(label: &str) -> Self {
        LossCurve { label: label.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, step: usize, loss: f32) {
        self.steps.push(step);
        self.losses.push(loss);
    }

    /// Mean loss over the last `k` recorded points (curve smoothing).
    pub fn tail_mean(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }
}

/// Write curves as a wide CSV: step, <label1>, <label2>, ...
/// Curves may have different lengths; missing cells are blank.
pub fn write_csv(path: impl AsRef<Path>, curves: &[&LossCurve]) -> Result<()> {
    let mut out = String::new();
    write!(out, "step")?;
    for c in curves {
        write!(out, ",{}", c.label)?;
    }
    writeln!(out)?;
    let max_len = curves.iter().map(|c| c.steps.len()).max().unwrap_or(0);
    for i in 0..max_len {
        let step = curves
            .iter()
            .find(|c| i < c.steps.len())
            .map(|c| c.steps[i])
            .unwrap_or(i);
        write!(out, "{step}")?;
        for c in curves {
            if i < c.losses.len() {
                write!(out, ",{:.5}", c.losses[i])?;
            } else {
                write!(out, ",")?;
            }
        }
        writeln!(out)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Throughput meter: tokens/sec with warmup exclusion (first `warmup`
/// laps are discarded -- artifact compilation and cache warmup).
pub struct Throughput {
    warmup: usize,
    laps: Vec<f64>,
    tokens_per_lap: usize,
    t0: Option<Instant>,
}

impl Throughput {
    pub fn new(tokens_per_lap: usize, warmup: usize) -> Self {
        Throughput { warmup, laps: Vec::new(), tokens_per_lap, t0: None }
    }

    pub fn start(&mut self) {
        self.t0 = Some(Instant::now());
    }

    pub fn lap(&mut self) {
        if let Some(t0) = self.t0.take() {
            self.laps.push(t0.elapsed().as_secs_f64());
        }
        self.t0 = Some(Instant::now());
    }

    pub fn measured_laps(&self) -> &[f64] {
        &self.laps[self.warmup.min(self.laps.len())..]
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let laps = self.measured_laps();
        if laps.is_empty() {
            return 0.0;
        }
        let total: f64 = laps.iter().sum();
        (laps.len() * self.tokens_per_lap) as f64 / total
    }

    pub fn mean_ms(&self) -> f64 {
        let laps = self.measured_laps();
        if laps.is_empty() {
            return 0.0;
        }
        laps.iter().sum::<f64>() / laps.len() as f64 * 1e3
    }

    pub fn median_ms(&self) -> f64 {
        let mut laps = self.measured_laps().to_vec();
        if laps.is_empty() {
            return 0.0;
        }
        laps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        laps[laps.len() / 2] * 1e3
    }
}

/// Fixed-width table printer for the bench harnesses (paper-table shaped
/// output).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:>w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_outcomes_buckets() {
        let o = ServeOutcomes { finished: 5, expired: 2, shed: 1, failed: 1, recovered: 3 };
        assert_eq!(o.total(), 9, "recovered overlaps finished, not added");
        assert!(!o.all_finished());
        let clean = ServeOutcomes { finished: 4, recovered: 1, ..Default::default() };
        assert!(clean.all_finished());
        assert_eq!(ServeOutcomes::default().total(), 0);
    }

    #[test]
    fn summary_order_stats() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0, 100.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 22.0).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 100.0);
        assert_eq!(s.p99, 100.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn summary_ignores_non_finite_samples() {
        // Used to panic in partial_cmp(..).unwrap(); now NaN/inf are dropped.
        let s = Summary::of(&[f64::NAN, 2.0, f64::INFINITY, 1.0, f64::NEG_INFINITY]);
        assert_eq!(s.n, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 2.0);
        let all_bad = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(all_bad.n, 0);
        assert_eq!(all_bad.max, 0.0);
    }

    #[test]
    fn loss_curve_tail_mean() {
        let mut c = LossCurve::new("x");
        for (i, l) in [5.0, 4.0, 3.0, 2.0].iter().enumerate() {
            c.push(i, *l);
        }
        assert!((c.tail_mean(2) - 2.5).abs() < 1e-6);
        assert!((c.tail_mean(100) - 3.5).abs() < 1e-6);
    }

    #[test]
    fn csv_output() {
        let mut a = LossCurve::new("a");
        a.push(0, 1.0);
        a.push(1, 0.5);
        let mut b = LossCurve::new("b");
        b.push(0, 2.0);
        let dir = std::env::temp_dir().join("lmoe_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.csv");
        write_csv(&p, &[&a, &b]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("step,a,b\n"));
        assert!(s.contains("0,1.00000,2.00000"));
        assert!(s.contains("1,0.50000,"));
    }

    #[test]
    fn health_board_counts_beats_and_restarts() {
        let hb = HealthBoard::new(3);
        hb.beat(0);
        hb.beat(0);
        hb.beat(2);
        hb.record_restart();
        let snap = hb.snapshot(
            CommFaultStats { timeouts: 1, ..Default::default() },
            CommTraffic { all_to_all_bytes: 64, all_to_all_ops: 2, ..Default::default() },
        );
        assert_eq!(snap.heartbeats, vec![2, 0, 1]);
        assert_eq!(snap.restarts, 1);
        assert_eq!(snap.comm.timeouts, 1);
        assert_eq!(snap.traffic.all_to_all_bytes, 64);
        assert_eq!(snap.traffic.total_bytes(), 64);
    }

    #[test]
    fn throughput_excludes_warmup() {
        let mut t = Throughput::new(100, 1);
        t.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        t.lap(); // warmup lap
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.lap();
        assert_eq!(t.measured_laps().len(), 1);
        assert!(t.tokens_per_sec() > 0.0);
    }
}
