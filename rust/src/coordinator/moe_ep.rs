//! Expert-parallel MoE dispatch (paper §2.2.3 EP) and the MoE execution
//! strategies of Table 4 (top).
//!
//! The router runs as an HLO artifact on each EP rank's local tokens; the
//! Rust dispatcher owns everything the paper attributes to the training
//! system: per-expert counting, capacity, the **all-to-all** token
//! exchange across the EP group, expert execution, the return all-to-all,
//! and gate-weighted combination.
//!
//! Execution strategies over the local experts:
//!  - `Loop`: one `moe_expert_cap_*` launch per expert over its
//!    capacity-padded group (the naive Megatron baseline),
//!  - `Grouped`: a single `moe_grouped_*` batched launch (GroupedGEMM),
//!  - `MegaBlocks`: exact-fit tiles -- tokens are packed per expert and
//!    only *occupied* `moe_expert_tile_*` launches are issued, so no
//!    capacity padding is computed at all.  Dynamic launch counts are
//!    exactly what static HLO cannot express and what block-sparse kernels
//!    buy on GPU; here the coordinator schedules them.
//!
//! All three produce identical outputs for tokens within capacity (tested
//! in rust/tests/moe.rs).

use anyhow::Result;
use std::rc::Rc;

use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Loop,
    Grouped,
    MegaBlocks,
}

pub struct MoeLayer {
    pub d: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub cap: usize,
    pub tile: usize,
    router: Rc<Executable>,
    expert_cap: Rc<Executable>,
    expert_tile: Rc<Executable>,
    grouped: Vec<(usize, Rc<Executable>)>, // (n_local, exe)
}

/// Expert weights: (w1, w3, w2) per expert.
pub struct ExpertWeights {
    pub w1: Vec<Tensor>,
    pub w3: Vec<Tensor>,
    pub w2: Vec<Tensor>,
}

impl ExpertWeights {
    /// Deterministic random init matching moe.py scaling.
    pub fn random(rng: &mut crate::rng::Rng, e: usize, d: usize, f: usize) -> Self {
        let mk = |rng: &mut crate::rng::Rng, rows: usize, cols: usize| {
            let scale = 1.0 / (rows as f32).sqrt();
            Tensor::f32(
                &[rows, cols],
                (0..rows * cols).map(|_| rng.normal() * scale).collect(),
            )
        };
        ExpertWeights {
            w1: (0..e).map(|_| mk(rng, d, f)).collect(),
            w3: (0..e).map(|_| mk(rng, d, f)).collect(),
            w2: (0..e).map(|_| mk(rng, f, d)).collect(),
        }
    }
}

impl MoeLayer {
    pub fn new(rt: &Runtime, name: &str) -> Result<Self> {
        let router = rt.load(&format!("moe_router_{name}"))?;
        let expert_cap = rt.load(&format!("moe_expert_cap_{name}"))?;
        let expert_tile = rt.load(&format!("moe_expert_tile_{name}"))?;
        let d = router.spec.meta_usize("d_model").unwrap();
        let e = router.spec.meta_usize("n_experts").unwrap();
        let top_k = router.spec.meta_usize("top_k").unwrap();
        let cap = expert_cap.spec.meta_usize("group").unwrap();
        let tile = expert_tile.spec.meta_usize("group").unwrap();
        let mut grouped = Vec::new();
        for e_local in [e, e / 2, e / 4, e / 8] {
            if e_local == 0 {
                continue;
            }
            if let Ok(exe) = rt.load(&format!("moe_grouped_{name}_e{e_local}")) {
                grouped.push((e_local, exe));
            }
        }
        Ok(MoeLayer {
            d,
            n_experts: e,
            top_k,
            cap,
            tile,
            router,
            expert_cap,
            expert_tile,
            grouped,
        })
    }

    /// Route local tokens: returns (gates (T,k), idx (T,k)).
    pub fn route(&self, router_w: &Tensor, x: &Tensor) -> Result<(Vec<f32>, Vec<i32>)> {
        let out = self.router.run(&[router_w, x])?;
        Ok((out[0].as_f32()?.to_vec(), out[1].as_i32()?.to_vec()))
    }

    /// Single-rank MoE layer over (T, d) tokens with the chosen strategy.
    /// Returns (y (T, d), per-expert token counts, launches issued).
    pub fn forward_local(
        &self,
        strategy: Strategy,
        router_w: &Tensor,
        weights: &ExpertWeights,
        x: &Tensor,
    ) -> Result<(Tensor, Vec<usize>, usize)> {
        let t = x.shape[0];
        let d = self.d;
        let xv = x.as_f32()?;
        let (gates, idx) = self.route(router_w, x)?;
        let k = self.top_k;

        // assignment lists per expert, in token order
        let mut assign: Vec<Vec<(usize, f32)>> = vec![Vec::new(); self.n_experts];
        for ti in 0..t {
            for j in 0..k {
                let e = idx[ti * k + j] as usize;
                assign[e].push((ti, gates[ti * k + j]));
            }
        }
        let counts: Vec<usize> = assign.iter().map(|a| a.len()).collect();

        let mut y = vec![0f32; t * d];
        let mut launches = 0usize;
        match strategy {
            Strategy::Loop => {
                for e in 0..self.n_experts {
                    let kept = assign[e].len().min(self.cap);
                    let mut buf = vec![0f32; self.cap * d];
                    for (s, &(ti, _)) in assign[e].iter().take(kept).enumerate() {
                        buf[s * d..(s + 1) * d]
                            .copy_from_slice(&xv[ti * d..(ti + 1) * d]);
                    }
                    let out = self.expert_cap.run(&[
                        &weights.w1[e], &weights.w3[e], &weights.w2[e],
                        &Tensor::f32(&[self.cap, d], buf),
                    ])?;
                    launches += 1;
                    let ov = out[0].as_f32()?;
                    for (s, &(ti, g)) in assign[e].iter().take(kept).enumerate() {
                        for c in 0..d {
                            y[ti * d + c] += g * ov[s * d + c];
                        }
                    }
                }
            }
            Strategy::Grouped => {
                let (e_local, exe) = self
                    .grouped
                    .iter()
                    .find(|(el, _)| *el == self.n_experts)
                    .ok_or_else(|| anyhow::anyhow!("no grouped artifact for e={}", self.n_experts))?;
                let e_local = *e_local;
                let mut buf = vec![0f32; e_local * self.cap * d];
                for e in 0..e_local {
                    let kept = assign[e].len().min(self.cap);
                    for (s, &(ti, _)) in assign[e].iter().take(kept).enumerate() {
                        let dst = (e * self.cap + s) * d;
                        buf[dst..dst + d].copy_from_slice(&xv[ti * d..(ti + 1) * d]);
                    }
                }
                // stacked weights (E, d, f) etc.
                let stack = |ws: &[Tensor]| -> Result<Tensor> {
                    let mut data = Vec::new();
                    for w in ws {
                        data.extend_from_slice(w.as_f32()?);
                    }
                    let mut shape = vec![ws.len()];
                    shape.extend_from_slice(&ws[0].shape);
                    Ok(Tensor::f32(&shape, data))
                };
                let out = exe.run(&[
                    &stack(&weights.w1)?, &stack(&weights.w3)?, &stack(&weights.w2)?,
                    &Tensor::f32(&[e_local, self.cap, d], buf),
                ])?;
                launches += 1;
                let ov = out[0].as_f32()?;
                for e in 0..e_local {
                    let kept = assign[e].len().min(self.cap);
                    for (s, &(ti, g)) in assign[e].iter().take(kept).enumerate() {
                        let src = (e * self.cap + s) * d;
                        for c in 0..d {
                            y[ti * d + c] += g * ov[src + c];
                        }
                    }
                }
            }
            Strategy::MegaBlocks => {
                // exact-fit tiles: ceil(count/tile) launches per expert,
                // no capacity drop, no padded FLOPs beyond the last tile.
                for e in 0..self.n_experts {
                    let n_e = assign[e].len();
                    let mut s0 = 0usize;
                    while s0 < n_e {
                        let take = (n_e - s0).min(self.tile);
                        let mut buf = vec![0f32; self.tile * d];
                        for (s, &(ti, _)) in
                            assign[e][s0..s0 + take].iter().enumerate()
                        {
                            buf[s * d..(s + 1) * d]
                                .copy_from_slice(&xv[ti * d..(ti + 1) * d]);
                        }
                        let out = self.expert_tile.run(&[
                            &weights.w1[e], &weights.w3[e], &weights.w2[e],
                            &Tensor::f32(&[self.tile, d], buf),
                        ])?;
                        launches += 1;
                        let ov = out[0].as_f32()?;
                        for (s, &(ti, g)) in
                            assign[e][s0..s0 + take].iter().enumerate()
                        {
                            for c in 0..d {
                                y[ti * d + c] += g * ov[s * d + c];
                            }
                        }
                        s0 += take;
                    }
                }
            }
        }
        Ok((Tensor::f32(&[t, d], y), counts, launches))
    }
}

/// Expert-parallel dispatch plan for one EP rank: which local tokens go to
/// which EP peer (expert owner), in deterministic order.
/// experts are block-partitioned: expert e lives on rank e / (E / ep_world).
pub struct EpPlan {
    pub ep_world: usize,
    pub experts_per_rank: usize,
    /// for each destination rank: (local token idx, expert local id, gate)
    pub sends: Vec<Vec<(usize, usize, f32)>>,
}

pub fn plan_dispatch(
    ep_world: usize,
    n_experts: usize,
    idx: &[i32],
    gates: &[f32],
    top_k: usize,
) -> EpPlan {
    let experts_per_rank = n_experts / ep_world;
    let mut sends: Vec<Vec<(usize, usize, f32)>> = vec![Vec::new(); ep_world];
    let t = idx.len() / top_k;
    for ti in 0..t {
        for j in 0..top_k {
            let e = idx[ti * top_k + j] as usize;
            let dst = e / experts_per_rank;
            sends[dst].push((ti, e % experts_per_rank, gates[ti * top_k + j]));
        }
    }
    EpPlan { ep_world, experts_per_rank, sends }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{check, Rng};

    #[test]
    fn dispatch_plan_is_a_partition() {
        // property: every (token, k) assignment appears in exactly one
        // destination list, routed to the rank owning its expert.
        check("ep_dispatch_partition", 64, |rng: &mut Rng| {
            let ep = 1 << rng.below(3);
            let e = ep * (1 + rng.below(4));
            let k = 1 + rng.below(3.min(e));
            let t = 1 + rng.below(64);
            let mut idx = Vec::with_capacity(t * k);
            let mut gates = Vec::with_capacity(t * k);
            for _ in 0..t * k {
                idx.push(rng.below(e) as i32);
                gates.push(rng.f32());
            }
            let plan = plan_dispatch(ep, e, &idx, &gates, k);
            let total: usize = plan.sends.iter().map(|s| s.len()).sum();
            assert_eq!(total, t * k);
            for (dst, sends) in plan.sends.iter().enumerate() {
                for &(ti, el, _) in sends {
                    let global_e = dst * plan.experts_per_rank + el;
                    assert!(ti < t);
                    // the original assignment must exist
                    assert!((0..k).any(|j| idx[ti * k + j] as usize == global_e));
                }
            }
        });
    }
}
