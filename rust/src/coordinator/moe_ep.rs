//! Expert-parallel MoE execution (paper §2.2.3 EP) and the MoE execution
//! strategies of Table 4 (top).
//!
//! The router runs as an HLO artifact on each EP rank's local tokens; the
//! Rust dispatcher owns everything the paper attributes to the training
//! system: per-expert counting, capacity, the **all-to-all** token
//! exchange across the EP group, expert execution, the return all-to-all,
//! and gate-weighted combination.
//!
//! Execution strategies over the local experts:
//!  - `Loop`: one `moe_expert_cap_*` launch per expert over its
//!    capacity-padded group (the naive Megatron baseline),
//!  - `Grouped`: a single `moe_grouped_*` batched launch (GroupedGEMM),
//!  - `MegaBlocks`: exact-fit tiles -- tokens are packed per expert and
//!    only *occupied* `moe_expert_tile_*` launches are issued, so no
//!    capacity padding is computed at all.  Dynamic launch counts are
//!    exactly what static HLO cannot express and what block-sparse kernels
//!    buy on GPU; here the coordinator schedules them.
//!
//! Multi-rank execution: [`forward_ep`] runs the full
//! dispatch -> local-expert execute -> combine pipeline over
//! `CommHandle::{a2a_post, a2a_wait}`.  Local experts are split into
//! *chunk groups* ([`EpCfg::chunk`] experts per shard), each group's
//! tokens travel as one all-to-all micro-shard, and in overlap mode the
//! scheduler posts shard c+1 and defers every return-shard wait so expert
//! compute on shard c runs while its neighbours are still exchanging --
//! the FSMoE-style pipelining.  Outputs are **bit-identical** to the
//! single-rank path for every strategy (including capacity drops):
//! per-destination send lists are stable-sorted by local expert so the
//! receive-side concatenation reproduces global token order, and the
//! combine accumulates in (EP rank asc, chunk group asc, row order) =
//! global expert-ascending order, exactly the order the single-rank
//! strategies use.
//!
//! Allocation discipline: a grow-only [`DispatchArena`] pools every
//! launch/pack/combine scratch buffer, and [`StackedExpertWeights`] caches
//! the (E, ..) grouped-GEMM weight stacks, so after one warmup step the
//! hot path performs no scratch reallocation (`DispatchArena::alloc_events`
//! stays flat -- asserted in benches/table4_moe.rs).  Expert compute is
//! abstracted behind [`ExpertCompute`] so tests and benches can run the
//! whole EP pipeline with a pure-Rust [`ReferenceExperts`] backend, no
//! artifacts or PJRT needed (PJRT executables are not `Send`; each EP
//! worker thread binds its own backend).

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::collectives::{A2aTicket, CommHandle};
use crate::json::Json;
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;
use crate::trace::Track;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Loop,
    Grouped,
    MegaBlocks,
}

impl Strategy {
    /// Whether the strategy drops tokens beyond per-expert capacity
    /// (MegaBlocks' exact-fit tiles never drop).
    pub fn capped(self) -> bool {
        !matches!(self, Strategy::MegaBlocks)
    }

    pub fn parse(s: &str) -> Result<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "loop" => Ok(Strategy::Loop),
            "grouped" => Ok(Strategy::Grouped),
            "megablocks" => Ok(Strategy::MegaBlocks),
            _ => Err(anyhow!(
                "unknown MoE strategy '{s}' (expected loop | grouped | megablocks)"
            )),
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Strategy::Loop => write!(f, "loop"),
            Strategy::Grouped => write!(f, "grouped"),
            Strategy::MegaBlocks => write!(f, "megablocks"),
        }
    }
}

/// MoE layer geometry, decoupled from artifacts so the EP engine and the
/// reference backend can run without a compiled manifest.
#[derive(Clone, Copy, Debug)]
pub struct MoeGeom {
    pub d: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub cap: usize,
    pub tile: usize,
}

// ---------------------------------------------------------------------------
// Grow-only dispatch arena.
// ---------------------------------------------------------------------------

/// Scratch-tensor lane: per-launch (cap,d) / (tile,d) packing buffer.
pub const LANE_LAUNCH: usize = 0;
/// Scratch-tensor lane: grouped (n_local, cap, d) packing buffer.
pub const LANE_GROUPED: usize = 1;
const N_TENSOR_LANES: usize = 2;

/// Vec lane: per-launch expert output staging.
pub const VLANE_LAUNCH_OUT: usize = 0;
/// Vec lane: single-rank expert-output slots.
pub const VLANE_SLOTS: usize = 1;
/// Vec lane: EP receive-side concatenated rows.
pub const VLANE_RECV: usize = 2;
/// Vec lane: EP receive-side output rows (with keep-flag column).
pub const VLANE_OUT: usize = 3;
const N_VEC_LANES: usize = 4;

/// Grow-only scratch buffers for MoE dispatch.  Every lane keeps its
/// high-water allocation; once shapes stabilise (after one warmup step)
/// `alloc_events()` stops moving -- the zero-realloc property the bench
/// asserts.  Buffers are handed out zeroed so padded launch rows match the
/// freshly-allocated buffers of the naive path bit-for-bit.
#[derive(Default)]
pub struct DispatchArena {
    tensors: Vec<Option<Tensor>>,
    vecs: Vec<Option<Vec<f32>>>,
    alloc_events: u64,
}

impl DispatchArena {
    pub fn new() -> Self {
        DispatchArena {
            tensors: (0..N_TENSOR_LANES).map(|_| None).collect(),
            vecs: (0..N_VEC_LANES).map(|_| None).collect(),
            alloc_events: 0,
        }
    }

    /// Number of times a lane actually had to (re)allocate.  Flat after
    /// warmup when shapes are stable.
    pub fn alloc_events(&self) -> u64 {
        self.alloc_events
    }

    /// Zeroed f32 tensor of `shape` in `lane`, reused in place when the
    /// shape matches the previous occupant.
    pub fn tensor(&mut self, lane: usize, shape: &[usize]) -> Result<&mut Tensor> {
        let reuse = self.tensors[lane]
            .as_ref()
            .is_some_and(|t| t.shape == shape);
        if reuse {
            for v in self.tensors[lane].as_mut().unwrap().as_f32_mut()? {
                *v = 0.0;
            }
        } else {
            self.alloc_events += 1;
            self.tensors[lane] = Some(Tensor::zeros(shape));
        }
        Ok(self.tensors[lane].as_mut().unwrap())
    }

    /// Immutable view of the lane's current tensor (after filling it via
    /// [`tensor`](Self::tensor)), for passing to a backend launch.
    pub fn tensor_ref(&self, lane: usize) -> &Tensor {
        self.tensors[lane]
            .as_ref()
            .expect("arena lane read before first fill")
    }

    /// Take a zeroed length-`n` scratch vec out of `lane` (ownership
    /// transfer, so it can live alongside later arena borrows).  Return it
    /// with [`put_vec`](Self::put_vec).
    pub fn take_vec(&mut self, lane: usize, n: usize) -> Vec<f32> {
        let mut v = self.vecs[lane].take().unwrap_or_default();
        if v.capacity() < n {
            self.alloc_events += 1;
        }
        v.clear();
        v.resize(n, 0.0);
        v
    }

    pub fn put_vec(&mut self, lane: usize, v: Vec<f32>) {
        self.vecs[lane] = Some(v);
    }
}

// ---------------------------------------------------------------------------
// Expert compute backends.
// ---------------------------------------------------------------------------

/// Backend that evaluates the expert MLPs on packed row buffers.  Rows are
/// independent (the expert MLP has no cross-row coupling), so any backend
/// is bit-identical between single-rank and EP execution as long as it is
/// deterministic per row.  `out` receives exactly `x.numel()` f32s.
pub trait ExpertCompute {
    /// One expert over a capacity-padded `(cap, d)` buffer.
    fn run_cap(&self, e: usize, x: &Tensor, out: &mut [f32]) -> Result<()>;
    /// One expert over an exact-fit `(tile, d)` buffer.
    fn run_tile(&self, e: usize, x: &Tensor, out: &mut [f32]) -> Result<()>;
    /// Experts `[e0, e0 + n_local)` batched over `(n_local, cap, d)`.
    fn run_grouped(&self, e0: usize, n_local: usize, x: &Tensor, out: &mut [f32])
        -> Result<()>;
}

/// Expert weights: (w1, w3, w2) per expert.
#[derive(Clone)]
pub struct ExpertWeights {
    pub w1: Vec<Tensor>,
    pub w3: Vec<Tensor>,
    pub w2: Vec<Tensor>,
}

impl ExpertWeights {
    /// Deterministic random init matching moe.py scaling.
    pub fn random(rng: &mut crate::rng::Rng, e: usize, d: usize, f: usize) -> Self {
        let mk = |rng: &mut crate::rng::Rng, rows: usize, cols: usize| {
            let scale = 1.0 / (rows as f32).sqrt();
            Tensor::f32(
                &[rows, cols],
                (0..rows * cols).map(|_| rng.normal() * scale).collect(),
            )
        };
        ExpertWeights {
            w1: (0..e).map(|_| mk(rng, d, f)).collect(),
            w3: (0..e).map(|_| mk(rng, d, f)).collect(),
            w2: (0..e).map(|_| mk(rng, f, d)).collect(),
        }
    }
}

/// One-time cache of stacked `(n_local, ..)` weight tensors for grouped
/// launches, keyed by the expert range.  Kills the per-forward `stack()`
/// copies the old Grouped path performed on every call.
#[derive(Default)]
pub struct StackedExpertWeights {
    cache: RefCell<HashMap<(usize, usize), Rc<(Tensor, Tensor, Tensor)>>>,
}

impl StackedExpertWeights {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stacked (w1, w3, w2) for experts `[e0, e0 + n)`, built on first use.
    pub fn get(
        &self,
        w: &ExpertWeights,
        e0: usize,
        n: usize,
    ) -> Result<Rc<(Tensor, Tensor, Tensor)>> {
        if let Some(s) = self.cache.borrow().get(&(e0, n)) {
            return Ok(s.clone());
        }
        let stack = |ws: &[Tensor]| -> Result<Tensor> {
            let mut data = Vec::new();
            for t in &ws[e0..e0 + n] {
                data.extend_from_slice(t.as_f32()?);
            }
            let mut shape = vec![n];
            shape.extend_from_slice(&ws[e0].shape);
            Ok(Tensor::f32(&shape, data))
        };
        let s = Rc::new((stack(&w.w1)?, stack(&w.w3)?, stack(&w.w2)?));
        self.cache.borrow_mut().insert((e0, n), s.clone());
        Ok(s)
    }
}

/// PJRT-artifact backend: the production path, binding a [`MoeLayer`]'s
/// compiled executables to a weight set.  Not `Send` (PJRT executables
/// hold raw pointers); each EP worker thread builds its own.
pub struct PjrtExperts<'a> {
    layer: &'a MoeLayer,
    weights: &'a ExpertWeights,
    stacked: StackedExpertWeights,
}

impl<'a> PjrtExperts<'a> {
    pub fn new(layer: &'a MoeLayer, weights: &'a ExpertWeights) -> Self {
        PjrtExperts { layer, weights, stacked: StackedExpertWeights::new() }
    }

    fn copy_out(res: &[Tensor], out: &mut [f32]) -> Result<()> {
        let v = res[0].as_f32()?;
        ensure!(v.len() == out.len(), "expert launch returned {} elems, expected {}",
                v.len(), out.len());
        out.copy_from_slice(v);
        Ok(())
    }
}

impl ExpertCompute for PjrtExperts<'_> {
    fn run_cap(&self, e: usize, x: &Tensor, out: &mut [f32]) -> Result<()> {
        let w = self.weights;
        let res = self
            .layer
            .expert_cap
            .run(&[&w.w1[e], &w.w3[e], &w.w2[e], x])?;
        Self::copy_out(&res, out)
    }

    fn run_tile(&self, e: usize, x: &Tensor, out: &mut [f32]) -> Result<()> {
        let w = self.weights;
        let res = self
            .layer
            .expert_tile
            .run(&[&w.w1[e], &w.w3[e], &w.w2[e], x])?;
        Self::copy_out(&res, out)
    }

    fn run_grouped(
        &self,
        e0: usize,
        n_local: usize,
        x: &Tensor,
        out: &mut [f32],
    ) -> Result<()> {
        let exe = self.layer.grouped_exe(n_local)?;
        let s = self.stacked.get(self.weights, e0, n_local)?;
        let res = exe.run(&[&s.0, &s.1, &s.2, x])?;
        Self::copy_out(&res, out)
    }
}

/// Pure-Rust SwiGLU backend: `y = (silu(x·w1) ⊙ (x·w3)) · w2`, evaluated
/// row by row in a fixed deterministic order.  Lets tests and benches run
/// the complete EP pipeline with zero artifacts, and is `Send` so each EP
/// worker thread can own a clone.
#[derive(Clone)]
pub struct ReferenceExperts {
    weights: ExpertWeights,
    d: usize,
    f: usize,
    scratch: RefCell<Vec<f32>>,
}

impl ReferenceExperts {
    pub fn new(weights: ExpertWeights) -> Self {
        let d = weights.w1[0].shape[0];
        let f = weights.w1[0].shape[1];
        ReferenceExperts { weights, d, f, scratch: RefCell::new(Vec::new()) }
    }

    fn rows(&self, e: usize, xv: &[f32], out: &mut [f32]) -> Result<()> {
        let (d, f) = (self.d, self.f);
        let w1 = self.weights.w1[e].as_f32()?;
        let w3 = self.weights.w3[e].as_f32()?;
        let w2 = self.weights.w2[e].as_f32()?;
        let mut scratch = self.scratch.borrow_mut();
        scratch.clear();
        scratch.resize(f, 0.0);
        let n = xv.len() / d;
        for r in 0..n {
            let x = &xv[r * d..(r + 1) * d];
            for j in 0..f {
                let mut h1 = 0.0f32;
                let mut h3 = 0.0f32;
                for c in 0..d {
                    h1 += x[c] * w1[c * f + j];
                    h3 += x[c] * w3[c * f + j];
                }
                let silu = h1 / (1.0 + (-h1).exp());
                scratch[j] = silu * h3;
            }
            let o = &mut out[r * d..(r + 1) * d];
            for (c, oc) in o.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (j, hj) in scratch.iter().enumerate() {
                    acc += hj * w2[j * d + c];
                }
                *oc = acc;
            }
        }
        Ok(())
    }
}

impl ExpertCompute for ReferenceExperts {
    fn run_cap(&self, e: usize, x: &Tensor, out: &mut [f32]) -> Result<()> {
        self.rows(e, x.as_f32()?, out)
    }

    fn run_tile(&self, e: usize, x: &Tensor, out: &mut [f32]) -> Result<()> {
        self.rows(e, x.as_f32()?, out)
    }

    fn run_grouped(
        &self,
        e0: usize,
        n_local: usize,
        x: &Tensor,
        out: &mut [f32],
    ) -> Result<()> {
        let per = x.shape[1] * x.shape[2];
        let xv = x.as_f32()?;
        for el in 0..n_local {
            self.rows(e0 + el, &xv[el * per..(el + 1) * per], &mut out[el * per..(el + 1) * per])?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Artifact-backed MoE layer.
// ---------------------------------------------------------------------------

pub struct MoeLayer {
    pub d: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub cap: usize,
    pub tile: usize,
    router: Rc<Executable>,
    expert_cap: Rc<Executable>,
    expert_tile: Rc<Executable>,
    grouped: Vec<(usize, Rc<Executable>)>, // (n_local, exe)
}

impl MoeLayer {
    pub fn new(rt: &Runtime, name: &str) -> Result<Self> {
        let router = rt.load(&format!("moe_router_{name}"))?;
        let expert_cap = rt.load(&format!("moe_expert_cap_{name}"))?;
        let expert_tile = rt.load(&format!("moe_expert_tile_{name}"))?;
        let d = router.spec.meta_usize("d_model").unwrap();
        let e = router.spec.meta_usize("n_experts").unwrap();
        let top_k = router.spec.meta_usize("top_k").unwrap();
        let cap = expert_cap.spec.meta_usize("group").unwrap();
        let tile = expert_tile.spec.meta_usize("group").unwrap();
        let mut grouped = Vec::new();
        for e_local in [e, e / 2, e / 4, e / 8] {
            if e_local == 0 {
                continue;
            }
            if let Ok(exe) = rt.load(&format!("moe_grouped_{name}_e{e_local}")) {
                grouped.push((e_local, exe));
            }
        }
        Ok(MoeLayer {
            d,
            n_experts: e,
            top_k,
            cap,
            tile,
            router,
            expert_cap,
            expert_tile,
            grouped,
        })
    }

    pub fn geom(&self) -> MoeGeom {
        MoeGeom {
            d: self.d,
            n_experts: self.n_experts,
            top_k: self.top_k,
            cap: self.cap,
            tile: self.tile,
        }
    }

    /// Grouped-GEMM executable for exactly `n_local` experts.  EP shards
    /// of E/2, E/4, E/8 experts per rank select the matching variant;
    /// errors name what was compiled so a miss is actionable.
    pub fn grouped_exe(&self, n_local: usize) -> Result<&Rc<Executable>> {
        self.grouped
            .iter()
            .find(|(el, _)| *el == n_local)
            .map(|(_, exe)| exe)
            .ok_or_else(|| {
                let have: Vec<usize> = self.grouped.iter().map(|(el, _)| *el).collect();
                anyhow!(
                    "no grouped MoE artifact for {n_local} local experts \
                     (compiled variants: {have:?}); regenerate artifacts or \
                     pick an EP degree whose experts-per-rank matches"
                )
            })
    }

    /// Bind a weight set to this layer's executables.  Hold the returned
    /// backend across steps: its [`StackedExpertWeights`] cache then
    /// stacks grouped-GEMM weights once instead of on every forward.
    pub fn bind<'a>(&'a self, weights: &'a ExpertWeights) -> PjrtExperts<'a> {
        PjrtExperts::new(self, weights)
    }

    /// Route local tokens: returns (gates (T,k), idx (T,k)).
    pub fn route(&self, router_w: &Tensor, x: &Tensor) -> Result<(Vec<f32>, Vec<i32>)> {
        let out = self.router.run(&[router_w, x])?;
        Ok((out[0].as_f32()?.to_vec(), out[1].as_i32()?.to_vec()))
    }

    /// Single-rank MoE layer over (T, d) tokens with the chosen strategy.
    /// Returns (y (T, d), per-expert token counts, launches issued).
    pub fn forward_local(
        &self,
        strategy: Strategy,
        router_w: &Tensor,
        weights: &ExpertWeights,
        x: &Tensor,
    ) -> Result<(Tensor, Vec<usize>, usize)> {
        let mut arena = DispatchArena::new();
        self.forward_local_with(strategy, router_w, weights, x, &mut arena)
    }

    /// `forward_local` with caller-owned scratch, so repeated steps reuse
    /// the arena's buffers and the stacked-weight cache lives in `backend`.
    pub fn forward_local_with(
        &self,
        strategy: Strategy,
        router_w: &Tensor,
        weights: &ExpertWeights,
        x: &Tensor,
        arena: &mut DispatchArena,
    ) -> Result<(Tensor, Vec<usize>, usize)> {
        let t = x.shape[0];
        let (gates, idx) = self.route(router_w, x)?;
        let backend = PjrtExperts::new(self, weights);
        let (y, counts, launches, _dropped) = forward_tokens(
            &backend,
            strategy,
            &self.geom(),
            &gates,
            &idx,
            x.as_f32()?,
            t,
            arena,
        )?;
        Ok((Tensor::f32(&[t, self.d], y), counts, launches))
    }
}

// ---------------------------------------------------------------------------
// Strategy launcher shared by the single-rank and EP paths.
// ---------------------------------------------------------------------------

/// Run experts `[e0, e0 + rows.len())` over per-expert row lists, writing
/// raw (ungated) expert outputs to `out[dst * ostride ..][..d]` for each
/// `(src, dst)` pair.  Rows are read from `xv[src * xstride ..][..d]`.
/// Capacity truncation is the caller's job: cap-strategy lists must
/// already be <= cap rows.  `launch_empty` preserves the single-rank Loop
/// contract of one launch per expert even when an expert got no tokens.
/// Returns the number of launches issued.
#[allow(clippy::too_many_arguments)]
fn exec_rows(
    backend: &dyn ExpertCompute,
    strategy: Strategy,
    geom: &MoeGeom,
    e0: usize,
    rows: &[Vec<(usize, usize)>],
    xv: &[f32],
    xstride: usize,
    out: &mut [f32],
    ostride: usize,
    arena: &mut DispatchArena,
    launch_empty: bool,
) -> Result<usize> {
    let (d, cap, tile) = (geom.d, geom.cap, geom.tile);
    let n_local = rows.len();
    let mut launches = 0usize;
    match strategy {
        Strategy::Loop => {
            for (el, list) in rows.iter().enumerate() {
                if list.is_empty() && !launch_empty {
                    continue;
                }
                ensure!(list.len() <= cap, "Loop launch over capacity");
                let mut lout = arena.take_vec(VLANE_LAUNCH_OUT, cap * d);
                {
                    let xt = arena.tensor(LANE_LAUNCH, &[cap, d])?;
                    let b = xt.as_f32_mut()?;
                    for (s, &(src, _)) in list.iter().enumerate() {
                        b[s * d..(s + 1) * d]
                            .copy_from_slice(&xv[src * xstride..src * xstride + d]);
                    }
                }
                backend.run_cap(e0 + el, arena.tensor_ref(LANE_LAUNCH), &mut lout)?;
                launches += 1;
                for (s, &(_, dst)) in list.iter().enumerate() {
                    out[dst * ostride..dst * ostride + d]
                        .copy_from_slice(&lout[s * d..(s + 1) * d]);
                }
                arena.put_vec(VLANE_LAUNCH_OUT, lout);
            }
        }
        Strategy::Grouped => {
            let total: usize = rows.iter().map(|l| l.len()).sum();
            if total > 0 || launch_empty {
                let mut lout = arena.take_vec(VLANE_LAUNCH_OUT, n_local * cap * d);
                {
                    let xt = arena.tensor(LANE_GROUPED, &[n_local, cap, d])?;
                    let b = xt.as_f32_mut()?;
                    for (el, list) in rows.iter().enumerate() {
                        ensure!(list.len() <= cap, "Grouped launch over capacity");
                        for (s, &(src, _)) in list.iter().enumerate() {
                            let o = (el * cap + s) * d;
                            b[o..o + d]
                                .copy_from_slice(&xv[src * xstride..src * xstride + d]);
                        }
                    }
                }
                backend.run_grouped(e0, n_local, arena.tensor_ref(LANE_GROUPED), &mut lout)?;
                launches += 1;
                for (el, list) in rows.iter().enumerate() {
                    for (s, &(_, dst)) in list.iter().enumerate() {
                        let src = (el * cap + s) * d;
                        out[dst * ostride..dst * ostride + d]
                            .copy_from_slice(&lout[src..src + d]);
                    }
                }
                arena.put_vec(VLANE_LAUNCH_OUT, lout);
            }
        }
        Strategy::MegaBlocks => {
            for (el, list) in rows.iter().enumerate() {
                let mut s0 = 0usize;
                while s0 < list.len() {
                    let take = (list.len() - s0).min(tile);
                    let mut lout = arena.take_vec(VLANE_LAUNCH_OUT, tile * d);
                    {
                        let xt = arena.tensor(LANE_LAUNCH, &[tile, d])?;
                        let b = xt.as_f32_mut()?;
                        for (s, &(src, _)) in list[s0..s0 + take].iter().enumerate() {
                            b[s * d..(s + 1) * d]
                                .copy_from_slice(&xv[src * xstride..src * xstride + d]);
                        }
                    }
                    backend.run_tile(e0 + el, arena.tensor_ref(LANE_LAUNCH), &mut lout)?;
                    launches += 1;
                    for (s, &(_, dst)) in list[s0..s0 + take].iter().enumerate() {
                        out[dst * ostride..dst * ostride + d]
                            .copy_from_slice(&lout[s * d..(s + 1) * d]);
                    }
                    arena.put_vec(VLANE_LAUNCH_OUT, lout);
                    s0 += take;
                }
            }
        }
    }
    Ok(launches)
}

/// Single-rank MoE forward over pre-routed tokens: builds per-expert
/// assignment lists from `(gates, idx)`, executes the strategy via
/// `backend`, and gate-combines.  Returns `(y, counts, launches, dropped)`.
/// This is the reference the EP path must match bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn forward_tokens(
    backend: &dyn ExpertCompute,
    strategy: Strategy,
    geom: &MoeGeom,
    gates: &[f32],
    idx: &[i32],
    xv: &[f32],
    t: usize,
    arena: &mut DispatchArena,
) -> Result<(Vec<f32>, Vec<usize>, usize, usize)> {
    let (d, k) = (geom.d, geom.top_k);
    ensure!(idx.len() == t * k && gates.len() == t * k,
            "router outputs do not match {t} tokens x top-{k}");
    // assignment lists per expert, in token order
    let mut assign: Vec<Vec<(usize, f32)>> = vec![Vec::new(); geom.n_experts];
    for ti in 0..t {
        for j in 0..k {
            let e = idx[ti * k + j] as usize;
            ensure!(e < geom.n_experts, "router index {e} out of range");
            assign[e].push((ti, gates[ti * k + j]));
        }
    }
    let counts: Vec<usize> = assign.iter().map(|a| a.len()).collect();

    // destination slots: expert-major enumeration of kept assignments
    let mut pairs: Vec<Vec<(usize, usize)>> = Vec::with_capacity(geom.n_experts);
    let mut slot = 0usize;
    let mut dropped = 0usize;
    for a in &assign {
        let kept = if strategy.capped() { a.len().min(geom.cap) } else { a.len() };
        dropped += a.len() - kept;
        let mut list = Vec::with_capacity(kept);
        for &(ti, _) in &a[..kept] {
            list.push((ti, slot));
            slot += 1;
        }
        pairs.push(list);
    }

    let mut slots_buf = arena.take_vec(VLANE_SLOTS, slot * d);
    let launches = exec_rows(
        backend, strategy, geom, 0, &pairs, xv, d, &mut slots_buf, d, arena, true,
    )?;

    // gate-weighted combine, expert-ascending then token order -- the f32
    // accumulation order every path must reproduce
    let mut y = vec![0f32; t * d];
    for (e, list) in pairs.iter().enumerate() {
        for (s, &(ti, dst)) in list.iter().enumerate() {
            let g = assign[e][s].1;
            let row = &slots_buf[dst * d..(dst + 1) * d];
            for (c, v) in row.iter().enumerate() {
                y[ti * d + c] += g * v;
            }
        }
    }
    arena.put_vec(VLANE_SLOTS, slots_buf);
    Ok((y, counts, launches, dropped))
}

// ---------------------------------------------------------------------------
// Expert-parallel dispatch plan + execution.
// ---------------------------------------------------------------------------

/// Expert-parallel dispatch plan for one EP rank: which local tokens go to
/// which EP peer (expert owner), in deterministic order.
/// experts are block-partitioned: expert e lives on rank e / (E / ep_world).
pub struct EpPlan {
    pub ep_world: usize,
    pub experts_per_rank: usize,
    /// for each destination rank: (local token idx, expert local id, gate)
    pub sends: Vec<Vec<(usize, usize, f32)>>,
}

pub fn plan_dispatch(
    ep_world: usize,
    n_experts: usize,
    idx: &[i32],
    gates: &[f32],
    top_k: usize,
) -> EpPlan {
    let experts_per_rank = n_experts / ep_world;
    let mut sends: Vec<Vec<(usize, usize, f32)>> = vec![Vec::new(); ep_world];
    let t = idx.len() / top_k;
    for ti in 0..t {
        for j in 0..top_k {
            let e = idx[ti * top_k + j] as usize;
            let dst = e / experts_per_rank;
            sends[dst].push((ti, e % experts_per_rank, gates[ti * top_k + j]));
        }
    }
    EpPlan { ep_world, experts_per_rank, sends }
}

/// EP execution config.
#[derive(Clone, Copy, Debug)]
pub struct EpCfg {
    pub strategy: Strategy,
    /// Local experts per all-to-all micro-shard; 0 = one shard with every
    /// local expert (unchunked).
    pub chunk: usize,
    /// Post shard c+1 and defer return-shard waits so expert compute
    /// overlaps in-flight exchanges (FSMoE-style); `false` = fully
    /// sequential dispatch -> compute -> combine per shard.
    pub overlap: bool,
}

impl Default for EpCfg {
    fn default() -> Self {
        EpCfg { strategy: Strategy::MegaBlocks, chunk: 0, overlap: true }
    }
}

/// Per-forward EP instrumentation.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpStats {
    /// all-to-all rounds (= ceil(experts_per_rank / chunk))
    pub rounds: usize,
    /// expert launches issued on this rank
    pub launches: usize,
    /// (token, expert) rows this rank sent out
    pub sent_rows: usize,
    /// rows received for this rank's experts
    pub recv_rows: usize,
    /// received rows dropped by per-expert capacity (cap strategies only)
    pub dropped_rows: usize,
    /// bytes this rank posted (dispatch + return shards)
    pub payload_bytes: u64,
    /// time blocked in `a2a_wait`
    pub comm_wait: Duration,
    /// time in expert compute
    pub compute: Duration,
    /// portion of `compute` during which >= 1 posted shard was in flight
    pub compute_overlapped: Duration,
}

impl EpStats {
    /// Fraction of expert-compute time that ran under an in-flight
    /// exchange: 0.0 = fully serialized, 1.0 = every launch overlapped.
    pub fn overlap_frac(&self) -> f64 {
        let c = self.compute.as_secs_f64();
        if c == 0.0 {
            0.0
        } else {
            self.compute_overlapped.as_secs_f64() / c
        }
    }
}

/// Receiver side of one chunked round: concatenate the shards from every
/// source rank, run this rank's expert chunk group, and build the return
/// shards.  Rows travel as (d + 1)-wide records -- data columns plus a
/// local-expert id on the way in, a keep-flag on the way out (0.0 marks a
/// capacity-dropped row the source must not accumulate).
#[allow(clippy::too_many_arguments)]
fn ep_exec_round(
    backend: &dyn ExpertCompute,
    cfg: &EpCfg,
    geom: &MoeGeom,
    rank: usize,
    epr: usize,
    chunk: usize,
    c: usize,
    recv: &[Tensor],
    arena: &mut DispatchArena,
    stats: &mut EpStats,
) -> Result<Vec<Tensor>> {
    let d = geom.d;
    let w = d + 1;
    let group = chunk.min(epr - c * chunk);
    let n_total: usize = recv.iter().map(|t| t.shape[0]).sum();
    stats.recv_rows += n_total;

    let mut recv_buf = arena.take_vec(VLANE_RECV, n_total * w);
    let mut off = 0usize;
    for t in recv {
        let v = t.as_f32()?;
        recv_buf[off..off + v.len()].copy_from_slice(v);
        off += v.len();
    }

    // per-expert row lists in concat (src-major) order == global token
    // order, truncated at capacity for cap strategies
    let mut lists: Vec<Vec<(usize, usize)>> = vec![Vec::new(); group];
    let mut out_buf = arena.take_vec(VLANE_OUT, n_total * w);
    for r in 0..n_total {
        let el = recv_buf[r * w + d] as usize;
        ensure!(
            el >= c * chunk && el < c * chunk + group,
            "shard row for expert {el} arrived in round {c}"
        );
        let eg = el - c * chunk;
        if cfg.strategy.capped() && lists[eg].len() >= geom.cap {
            stats.dropped_rows += 1;
            continue; // keep-flag stays 0.0
        }
        lists[eg].push((r, r));
        out_buf[r * w + d] = 1.0;
    }

    let e0 = rank * epr + c * chunk;
    stats.launches += exec_rows(
        backend, cfg.strategy, geom, e0, &lists, &recv_buf, w, &mut out_buf, w,
        arena, false,
    )?;

    // slice the concat output back into one return shard per source rank
    let mut rets = Vec::with_capacity(recv.len());
    let mut off = 0usize;
    for t in recv {
        let n = t.shape[0];
        let data = out_buf[off * w..(off + n) * w].to_vec();
        off += n;
        let ret = Tensor::f32(&[n, w], data);
        stats.payload_bytes += ret.size_bytes() as u64;
        rets.push(ret);
    }
    arena.put_vec(VLANE_RECV, recv_buf);
    arena.put_vec(VLANE_OUT, out_buf);
    Ok(rets)
}

/// Expert-parallel MoE forward on one EP rank (call SPMD on every rank of
/// `comm`'s group).  `gates`/`idx` are this rank's router outputs over its
/// local `(t, d)` tokens `x`; `backend` must hold the full replicated
/// expert weight set (each rank computes experts
/// `[rank * E/world, (rank+1) * E/world)`).
///
/// Pipeline per chunk group: dispatch all-to-all (tokens sorted by local
/// expert so receive order reproduces global token order) -> local expert
/// execute -> return all-to-all -> gate-weighted combine in (EP rank asc,
/// group asc, row order), which is exactly global expert-ascending order.
/// Outputs are therefore bit-identical to [`forward_tokens`] over the
/// concatenated batch, for every strategy and any `chunk`/`overlap`
/// setting.
#[allow(clippy::too_many_arguments)]
pub fn forward_ep(
    comm: &CommHandle,
    backend: &dyn ExpertCompute,
    cfg: &EpCfg,
    geom: &MoeGeom,
    gates: &[f32],
    idx: &[i32],
    x: &Tensor,
    arena: &mut DispatchArena,
) -> Result<(Tensor, EpStats)> {
    let world = comm.world;
    let (d, k, e) = (geom.d, geom.top_k, geom.n_experts);
    ensure!(e % world == 0, "n_experts {e} not divisible by ep_world {world}");
    let epr = e / world;
    let chunk = if cfg.chunk == 0 { epr } else { cfg.chunk.min(epr) };
    let rounds = epr.div_ceil(chunk);
    let t = x.shape[0];
    let xv = x.as_f32()?;
    ensure!(x.shape == [t, d], "x must be (T, d)");

    let mut stats = EpStats { rounds, ..Default::default() };

    // Send lists, stable-sorted by destination-local expert: within one
    // (src, dst) pair the receiver then sees rows grouped by expert in
    // original token order, and src-major concatenation on the receiver
    // reproduces the global token order of the single-rank reference.
    let plan = plan_dispatch(world, e, idx, gates, k);
    let mut sends = plan.sends;
    for s in &mut sends {
        s.sort_by_key(|&(_, el, _)| el);
    }
    stats.sent_rows = sends.iter().map(|s| s.len()).sum();

    // per-destination round boundaries over the sorted lists
    let w = d + 1;
    let mut offs: Vec<Vec<usize>> = Vec::with_capacity(world);
    for s in &sends {
        let mut o = vec![0usize; rounds + 1];
        let mut i = 0usize;
        for (c, oc) in o.iter_mut().enumerate().skip(1) {
            let lim = c * chunk;
            while i < s.len() && s[i].1 < lim {
                i += 1;
            }
            *oc = i;
        }
        o[rounds] = s.len();
        offs.push(o);
    }

    let build_shard = |c: usize| -> (Vec<Tensor>, u64) {
        let mut parts = Vec::with_capacity(world);
        let mut bytes = 0u64;
        for dst in 0..world {
            let rows = &sends[dst][offs[dst][c]..offs[dst][c + 1]];
            let mut data = Vec::with_capacity(rows.len() * w);
            for &(ti, el, _g) in rows {
                data.extend_from_slice(&xv[ti * d..(ti + 1) * d]);
                data.push(el as f32);
            }
            let part = Tensor::f32(&[rows.len(), w], data);
            bytes += part.size_bytes() as u64;
            parts.push(part);
        }
        (parts, bytes)
    };

    // dispatch / execute / return, per round
    let trace = comm.tracer().clone();
    let ep_track = Track::new("ep", comm.rank as u64);
    // The per-round spans below carry the *same* measured durations that
    // feed EpStats, so obs::span_overlap_frac re-derives overlap_frac
    // from the trace and tests can cross-check the two.
    let mut returns: Vec<Vec<Tensor>> = Vec::with_capacity(rounds);
    if cfg.overlap {
        let mut data_tk: VecDeque<A2aTicket> = VecDeque::new();
        let mut ret_tk: Vec<A2aTicket> = Vec::new();
        let mut outstanding = 0usize;
        let (parts, bytes) = build_shard(0);
        stats.payload_bytes += bytes;
        if trace.on() {
            trace.instant(
                ep_track.clone(),
                "ep",
                "ep.dispatch.post",
                0,
                vec![
                    ("round".to_string(), Json::from(0u64)),
                    ("bytes".to_string(), Json::from(bytes)),
                ],
            );
        }
        data_tk.push_back(comm.a2a_post(parts)?);
        outstanding += 1;
        for c in 0..rounds {
            if c + 1 < rounds {
                let (parts, bytes) = build_shard(c + 1);
                stats.payload_bytes += bytes;
                if trace.on() {
                    trace.instant(
                        ep_track.clone(),
                        "ep",
                        "ep.dispatch.post",
                        (c + 1) as u64,
                        vec![
                            ("round".to_string(), Json::from(c + 1)),
                            ("bytes".to_string(), Json::from(bytes)),
                        ],
                    );
                }
                data_tk.push_back(comm.a2a_post(parts)?);
                outstanding += 1;
            }
            let tk = data_tk.pop_front().unwrap();
            let t0 = Instant::now();
            let recv = comm.a2a_wait(tk)?;
            let wait_dt = t0.elapsed();
            stats.comm_wait += wait_dt;
            outstanding -= 1;
            if trace.on() {
                trace.span_timed(
                    ep_track.clone(),
                    "ep",
                    "ep.wait.data",
                    c as u64,
                    0,
                    wait_dt,
                    vec![("round".to_string(), Json::from(c))],
                );
            }
            let t0 = Instant::now();
            let rets = ep_exec_round(
                backend, cfg, geom, comm.rank, epr, chunk, c, &recv, arena, &mut stats,
            )?;
            let dt = t0.elapsed();
            stats.compute += dt;
            let overlapped = outstanding > 0;
            if overlapped {
                stats.compute_overlapped += dt;
            }
            if trace.on() {
                trace.span_timed(
                    ep_track.clone(),
                    "ep",
                    "ep.expert",
                    c as u64,
                    0,
                    dt,
                    vec![
                        ("round".to_string(), Json::from(c)),
                        ("overlapped".to_string(), Json::from(overlapped)),
                    ],
                );
            }
            ret_tk.push(comm.a2a_post(rets)?);
            outstanding += 1;
        }
        for (c, tk) in ret_tk.into_iter().enumerate() {
            let t0 = Instant::now();
            returns.push(comm.a2a_wait(tk)?);
            let wait_dt = t0.elapsed();
            stats.comm_wait += wait_dt;
            if trace.on() {
                trace.span_timed(
                    ep_track.clone(),
                    "ep",
                    "ep.wait.return",
                    c as u64,
                    0,
                    wait_dt,
                    vec![("round".to_string(), Json::from(c))],
                );
            }
        }
    } else {
        for c in 0..rounds {
            let (parts, bytes) = build_shard(c);
            stats.payload_bytes += bytes;
            if trace.on() {
                trace.instant(
                    ep_track.clone(),
                    "ep",
                    "ep.dispatch.post",
                    c as u64,
                    vec![
                        ("round".to_string(), Json::from(c)),
                        ("bytes".to_string(), Json::from(bytes)),
                    ],
                );
            }
            let tk = comm.a2a_post(parts)?;
            let t0 = Instant::now();
            let recv = comm.a2a_wait(tk)?;
            let wait_dt = t0.elapsed();
            stats.comm_wait += wait_dt;
            if trace.on() {
                trace.span_timed(
                    ep_track.clone(),
                    "ep",
                    "ep.wait.data",
                    c as u64,
                    0,
                    wait_dt,
                    vec![("round".to_string(), Json::from(c))],
                );
            }
            let t0 = Instant::now();
            let rets = ep_exec_round(
                backend, cfg, geom, comm.rank, epr, chunk, c, &recv, arena, &mut stats,
            )?;
            let dt = t0.elapsed();
            stats.compute += dt;
            if trace.on() {
                trace.span_timed(
                    ep_track.clone(),
                    "ep",
                    "ep.expert",
                    c as u64,
                    0,
                    dt,
                    vec![
                        ("round".to_string(), Json::from(c)),
                        ("overlapped".to_string(), Json::from(false)),
                    ],
                );
            }
            let tk = comm.a2a_post(rets)?;
            let t0 = Instant::now();
            returns.push(comm.a2a_wait(tk)?);
            let wait_dt = t0.elapsed();
            stats.comm_wait += wait_dt;
            if trace.on() {
                trace.span_timed(
                    ep_track.clone(),
                    "ep",
                    "ep.wait.return",
                    c as u64,
                    0,
                    wait_dt,
                    vec![("round".to_string(), Json::from(c))],
                );
            }
        }
    }

    // combine: dst asc, round asc, rows in sorted send order -- for every
    // token that is global expert-ascending accumulation, matching the
    // single-rank reference bit-for-bit
    let t0 = Instant::now();
    let mut y = vec![0f32; t * d];
    for dst in 0..world {
        for (c, round_ret) in returns.iter().enumerate() {
            let meta = &sends[dst][offs[dst][c]..offs[dst][c + 1]];
            let rv = round_ret[dst].as_f32()?;
            ensure!(rv.len() == meta.len() * w, "return shard shape mismatch");
            for (r, &(ti, _el, g)) in meta.iter().enumerate() {
                if rv[r * w + d] == 0.0 {
                    continue; // dropped at capacity on the receiver
                }
                for c2 in 0..d {
                    y[ti * d + c2] += g * rv[r * w + c2];
                }
            }
        }
    }
    if trace.on() {
        trace.span_timed(
            ep_track,
            "ep",
            "ep.combine",
            rounds as u64,
            0,
            t0.elapsed(),
            vec![("rows".to_string(), Json::from(stats.sent_rows))],
        );
    }
    Ok((Tensor::f32(&[t, d], y), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{check, Rng};

    #[test]
    fn dispatch_plan_is_a_partition() {
        // property: every (token, k) assignment appears in exactly one
        // destination list, routed to the rank owning its expert.
        check("ep_dispatch_partition", 64, |rng: &mut Rng| {
            let ep = 1 << rng.below(3);
            let e = ep * (1 + rng.below(4));
            let k = 1 + rng.below(3.min(e));
            let t = 1 + rng.below(64);
            let mut idx = Vec::with_capacity(t * k);
            let mut gates = Vec::with_capacity(t * k);
            for _ in 0..t * k {
                idx.push(rng.below(e) as i32);
                gates.push(rng.f32());
            }
            let plan = plan_dispatch(ep, e, &idx, &gates, k);
            let total: usize = plan.sends.iter().map(|s| s.len()).sum();
            assert_eq!(total, t * k);
            for (dst, sends) in plan.sends.iter().enumerate() {
                for &(ti, el, _) in sends {
                    let global_e = dst * plan.experts_per_rank + el;
                    assert!(ti < t);
                    // the original assignment must exist
                    assert!((0..k).any(|j| idx[ti * k + j] as usize == global_e));
                }
            }
        });
    }

    fn toy_setup(rng: &mut Rng, e: usize, d: usize, f: usize, t: usize, k: usize)
        -> (ReferenceExperts, MoeGeom, Vec<f32>, Vec<i32>, Vec<f32>) {
        let weights = ExpertWeights::random(rng, e, d, f);
        let geom = MoeGeom { d, n_experts: e, top_k: k, cap: 4, tile: 2 };
        let xv: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
        let mut idx = Vec::with_capacity(t * k);
        let mut gates = Vec::with_capacity(t * k);
        for _ in 0..t * k {
            idx.push(rng.below(e) as i32);
            gates.push(rng.f32());
        }
        (ReferenceExperts::new(weights), geom, gates, idx, xv)
    }

    #[test]
    fn strategies_agree_on_reference_backend() {
        // within capacity, all three strategies produce identical outputs;
        // this is the single-rank invariant the EP path inherits.
        check("moe_strategies_agree", 16, |rng: &mut Rng| {
            let (be, geom, gates, idx, xv) = toy_setup(rng, 4, 3, 5, 6, 2);
            let mut arena = DispatchArena::new();
            let (y_mb, counts, _l, drop_mb) = forward_tokens(
                &be, Strategy::MegaBlocks, &geom, &gates, &idx, &xv, 6, &mut arena,
            ).unwrap();
            assert_eq!(drop_mb, 0);
            assert_eq!(counts.iter().sum::<usize>(), 12);
            if counts.iter().all(|&c| c <= geom.cap) {
                for s in [Strategy::Loop, Strategy::Grouped] {
                    let (y, _, _, dropped) = forward_tokens(
                        &be, s, &geom, &gates, &idx, &xv, 6, &mut arena,
                    ).unwrap();
                    assert_eq!(dropped, 0);
                    for (a, b) in y.iter().zip(&y_mb) {
                        assert!((a - b).abs() < 1e-4, "{s}: {a} vs {b}");
                    }
                }
            }
        });
    }

    #[test]
    fn loop_launches_every_expert_grouped_launches_once() {
        let mut rng = Rng::new(7);
        let (be, geom, gates, idx, xv) = toy_setup(&mut rng, 4, 3, 5, 6, 2);
        let mut arena = DispatchArena::new();
        let (_, _, l_loop, _) = forward_tokens(
            &be, Strategy::Loop, &geom, &gates, &idx, &xv, 6, &mut arena,
        ).unwrap();
        assert_eq!(l_loop, geom.n_experts);
        let (_, counts, l_grp, _) = forward_tokens(
            &be, Strategy::Grouped, &geom, &gates, &idx, &xv, 6, &mut arena,
        ).unwrap();
        assert_eq!(l_grp, 1);
        let (_, _, l_mb, _) = forward_tokens(
            &be, Strategy::MegaBlocks, &geom, &gates, &idx, &xv, 6, &mut arena,
        ).unwrap();
        let want: usize = counts.iter().map(|c| c.div_ceil(geom.tile)).sum();
        assert_eq!(l_mb, want);
    }

    #[test]
    fn arena_allocs_go_flat_after_warmup() {
        let mut rng = Rng::new(11);
        let (be, geom, gates, idx, xv) = toy_setup(&mut rng, 4, 3, 5, 6, 2);
        let mut arena = DispatchArena::new();
        for s in [Strategy::Loop, Strategy::Grouped, Strategy::MegaBlocks] {
            // warmup step sizes the lanes for this strategy
            forward_tokens(&be, s, &geom, &gates, &idx, &xv, 6, &mut arena).unwrap();
            let after_warmup = arena.alloc_events();
            for _ in 0..5 {
                forward_tokens(&be, s, &geom, &gates, &idx, &xv, 6, &mut arena).unwrap();
            }
            assert_eq!(arena.alloc_events(), after_warmup, "{s} reallocated");
        }
    }

    #[test]
    fn capacity_truncation_drops_in_token_order() {
        // one expert, cap 4, 6 tokens all routed to it: the last 2 drop
        let mut rng = Rng::new(3);
        let weights = ExpertWeights::random(&mut rng, 1, 2, 3);
        let be = ReferenceExperts::new(weights);
        let geom = MoeGeom { d: 2, n_experts: 1, top_k: 1, cap: 4, tile: 2 };
        let xv: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let gates = vec![1.0f32; 6];
        let idx = vec![0i32; 6];
        let mut arena = DispatchArena::new();
        let (y, counts, _, dropped) = forward_tokens(
            &be, Strategy::Loop, &geom, &gates, &idx, &xv, 6, &mut arena,
        ).unwrap();
        assert_eq!(counts, vec![6]);
        assert_eq!(dropped, 2);
        // dropped tokens get zero output
        assert_eq!(&y[8..12], &[0.0, 0.0, 0.0, 0.0]);
        assert!(y[0] != 0.0 || y[1] != 0.0);
    }
}
