//! Evaluation harness (paper Tables 5/6 substitution -- see DESIGN.md):
//! held-out perplexity on the synthetic corpus, plus a recall suite
//! (phonebook lookup / needle-in-a-haystack) that exercises exactly the
//! capability the paper's hybrid-vs-pure comparison turns on.

use anyhow::Result;

use crate::data::{self, RecallEpisode};
use crate::inference::{greedy, LsmDecoder};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{Bundle, Tensor};

/// Held-out perplexity via the `eval_loss_*` artifact.
pub fn perplexity(
    rt: &Runtime,
    tag: &str,
    params: &Bundle,
    batch: usize,
    seq: usize,
    batches: usize,
    seed: u64,
) -> Result<f64> {
    let exe = rt.load(&format!("eval_loss_{tag}_b{batch}n{seq}"))?;
    let var = rt.manifest.variant(tag)?;
    let mut lm = data::ZipfLm::new(var.config.vocab, seed);
    let mut total = 0.0f64;
    for _ in 0..batches {
        let b = data::batch_from_stream(&mut lm, batch, seq);
        let out = exe.run_bundled(&[params], &[&b.tokens, &b.targets])?;
        total += out[1].item_f32()? as f64; // ce
    }
    Ok((total / batches as f64).exp())
}

#[derive(Clone, Debug, Default)]
pub struct RecallReport {
    pub episodes: usize,
    pub correct: usize,
}

impl RecallReport {
    pub fn accuracy(&self) -> f64 {
        if self.episodes == 0 {
            0.0
        } else {
            self.correct as f64 / self.episodes as f64
        }
    }
}

/// Run recall episodes through a decoder: feed the prompt token by token,
/// then check whether greedy decoding emits the answer token.
/// The decoder's batch lane 0 carries the episode (other lanes idle).
pub fn recall_eval(
    decoder: &mut LsmDecoder,
    episodes: &[RecallEpisode],
) -> Result<RecallReport> {
    let b = decoder.batch;
    let mut report = RecallReport::default();
    for ep in episodes {
        decoder.reset();
        let mut logits = None;
        for (pos, &tok) in ep.prompt.iter().enumerate() {
            let t = Tensor::i32(&[b], vec![tok; b]);
            logits = Some(decoder.step(&t, pos as i32)?);
        }
        let pred = greedy(&logits.unwrap())?;
        report.episodes += 1;
        if pred.as_i32()?[0] == ep.answer {
            report.correct += 1;
        }
    }
    Ok(report)
}

/// Build a deterministic recall suite.
pub fn make_suite(
    vocab: usize,
    n_phonebook: usize,
    pairs: usize,
    n_niah: usize,
    haystack: usize,
    seed: u64,
) -> Vec<RecallEpisode> {
    let mut rng = Rng::new(seed);
    let mut suite = Vec::new();
    for _ in 0..n_phonebook {
        suite.push(data::phonebook_episode(&mut rng, vocab, pairs));
    }
    for _ in 0..n_niah {
        suite.push(data::niah_episode(&mut rng, vocab, haystack));
    }
    suite
}
