//! Minimal bench harness (offline substitute for criterion): timed warmup
//! + measured iterations, median/mean reporting, and paper-table printing
//! via `coordinator::metrics::Table`.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
}

/// Run `f` for `warmup` + `iters` iterations and time each measured one.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut laps = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        laps.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    laps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: laps.iter().sum::<f64>() / laps.len() as f64,
        median_ms: laps[laps.len() / 2],
        min_ms: laps[0],
    }
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:40} {:4} iters  mean {:9.3} ms  median {:9.3} ms  min {:9.3} ms",
            self.name, self.iters, self.mean_ms, self.median_ms, self.min_ms
        );
    }
}
