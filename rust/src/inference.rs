//! Inference driver (paper Fig. 5): autoregressive decoding from Rust over
//! the `decode_*` artifacts.
//!
//! Linear-MoE models carry one constant-size (Dk, Dv) state per head per
//! layer -> constant per-token latency and memory.  The attention Baseline
//! carries a KV cache; we allocate it as a power-of-two **staircase**
//! (decode_..._n{128,256,...} artifacts): step t runs the smallest cache
//! >= t, mirroring how paged/banded serving systems grow the cache, and
//! giving per-token cost that grows with position -- the Fig. 5 contrast.
//!
//! The `Decoder` trait abstracts the batched step function plus per-lane
//! state check-in/out, so the continuous-batching serving engine
//! (`crate::serve`) can drive the PJRT decoders and the artifact-free
//! reference backends through one interface.

use anyhow::Result;
use std::rc::Rc;

use crate::runtime::{Executable, LeafSpec, Runtime, Variant};
use crate::tensor::{Bundle, Data, Tensor};

pub struct DecodeStats {
    pub tokens: usize,
    pub secs: f64,
    /// modeled state bytes at the final position (memcost)
    pub state_bytes: usize,
}

/// Zero tensor matching a manifest leaf spec (dtype-dispatched).
pub fn zeros_for_spec(spec: &LeafSpec) -> Tensor {
    if spec.dtype.contains("int") {
        Tensor::i32(&spec.shape, vec![0; spec.numel()])
    } else {
        Tensor::zeros(&spec.shape)
    }
}

/// Decode state for one model: per-layer tensors in manifest order.
pub struct DecodeState {
    pub tensors: Vec<Tensor>,
}

impl DecodeState {
    /// Fresh zero state from manifest leaf specs.
    pub fn from_specs(specs: &[LeafSpec]) -> Self {
        DecodeState { tensors: specs.iter().map(zeros_for_spec).collect() }
    }

    /// Zero all state tensors in place (keeps shapes, dtypes, allocations).
    pub fn reset(&mut self) {
        for t in &mut self.tensors {
            t.fill_zero();
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }
}

fn init_state(spec: &crate::runtime::ArtifactSpec, n_params: usize) -> DecodeState {
    // state leaves sit between params and (token, pos) in the arg list.
    let n_args = spec.args.len();
    DecodeState::from_specs(&spec.args[n_params..n_args - 2])
}

/// Grow decode-state tensors into the shapes of `specs`, preserving dtype
/// and contents.  Same-shape tensors (constant-size LSM states, position
/// counters) ride along unchanged; tensors whose shape grows (KV caches)
/// get the overlapping hyperrectangle of the old contents copied into the
/// front of a zeroed tensor, for any rank and both dtypes.
pub fn grow_state(old: &[Tensor], specs: &[LeafSpec]) -> Result<Vec<Tensor>> {
    anyhow::ensure!(
        old.len() == specs.len(),
        "state arity changed across staircase: {} -> {}",
        old.len(),
        specs.len()
    );
    old.iter().zip(specs).map(|(o, s)| grow_tensor(o, s)).collect()
}

fn grow_tensor(old: &Tensor, spec: &LeafSpec) -> Result<Tensor> {
    let want_int = spec.dtype.contains("int");
    anyhow::ensure!(
        old.is_f32() != want_int,
        "state dtype changed across staircase: {} -> {}",
        if old.is_f32() { "f32" } else { "i32" },
        spec.dtype
    );
    if old.shape == spec.shape {
        return Ok(old.clone());
    }
    anyhow::ensure!(
        old.shape.len() == spec.shape.len() && !old.shape.is_empty(),
        "cannot grow state {:?} -> {:?}",
        old.shape,
        spec.shape
    );
    let mut new = zeros_for_spec(spec);
    let rank = spec.shape.len();
    let min: Vec<usize> = old
        .shape
        .iter()
        .zip(&spec.shape)
        .map(|(&a, &b)| a.min(b))
        .collect();
    let row = min[rank - 1];
    let outer: usize = min[..rank - 1].iter().product();
    let strides = |shape: &[usize]| -> Vec<usize> {
        (0..rank - 1)
            .map(|d| shape[d + 1..].iter().product())
            .collect()
    };
    let so = strides(&old.shape);
    let sn = strides(&spec.shape);
    let mut idx = vec![0usize; rank - 1];
    if row > 0 {
        for _ in 0..outer {
            let off_o: usize = idx.iter().zip(&so).map(|(i, s)| i * s).sum();
            let off_n: usize = idx.iter().zip(&sn).map(|(i, s)| i * s).sum();
            match (&old.data, &mut new.data) {
                (Data::F32(src), Data::F32(dst)) => {
                    dst[off_n..off_n + row].copy_from_slice(&src[off_o..off_o + row])
                }
                (Data::I32(src), Data::I32(dst)) => {
                    dst[off_n..off_n + row].copy_from_slice(&src[off_o..off_o + row])
                }
                _ => unreachable!("dtype checked above"),
            }
            for d in (0..rank - 1).rev() {
                idx[d] += 1;
                if idx[d] < min[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }
    Ok(new)
}

// ---------------------------------------------------------------------------
// Lane state: one request's slice of the batched decode state.
// ---------------------------------------------------------------------------

/// One lane's recurrent state, checked out of (or into) a batched decoder:
/// the per-state-tensor slabs at a fixed batch index, shapes without the
/// leading batch dim.  Buffers are reused across check-outs when shapes
/// match, so steady-state swapping allocates nothing (`reallocs` counts
/// the times a slot had to be (re)allocated).
#[derive(Clone, Debug, Default)]
pub struct LaneState {
    pub tensors: Vec<Tensor>,
    pub reallocs: u64,
}

impl LaneState {
    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// Slot `i` as a tensor of `shape`/dtype, reusing the existing buffer
    /// when it already matches (no realloc on the steady-state swap path).
    pub fn slot(&mut self, i: usize, shape: &[usize], is_f32: bool) -> &mut Tensor {
        while self.tensors.len() <= i {
            self.tensors.push(Tensor::f32(&[0], vec![]));
        }
        let stale = self.tensors[i].shape.as_slice() != shape
            || self.tensors[i].is_f32() != is_f32;
        if stale {
            self.reallocs += 1;
            self.tensors[i] = if is_f32 {
                Tensor::zeros(shape)
            } else {
                Tensor::i32(shape, vec![0; shape.iter().product()])
            };
        }
        &mut self.tensors[i]
    }
}

/// Copy lane `lane` of each (B, ...)-shaped state tensor into `out`.
pub fn save_lane_slices(
    tensors: &[Tensor],
    batch: usize,
    lane: usize,
    out: &mut LaneState,
) -> Result<()> {
    anyhow::ensure!(lane < batch, "lane {lane} out of range (batch {batch})");
    for (i, t) in tensors.iter().enumerate() {
        anyhow::ensure!(
            !t.shape.is_empty() && t.shape[0] == batch,
            "state tensor {i} ({:?}) is not lane-separable over batch {batch}",
            t.shape
        );
        let n = t.numel() / batch;
        let dst = out.slot(i, &t.shape[1..], t.is_f32());
        match (&t.data, &mut dst.data) {
            (Data::F32(src), Data::F32(d)) => {
                d.copy_from_slice(&src[lane * n..(lane + 1) * n])
            }
            (Data::I32(src), Data::I32(d)) => {
                d.copy_from_slice(&src[lane * n..(lane + 1) * n])
            }
            _ => unreachable!("slot dtype matches source"),
        }
    }
    out.tensors.truncate(tensors.len());
    Ok(())
}

/// Copy a saved lane state back into lane `lane` of the batched tensors.
pub fn load_lane_slices(
    tensors: &mut [Tensor],
    batch: usize,
    lane: usize,
    src: &LaneState,
) -> Result<()> {
    anyhow::ensure!(lane < batch, "lane {lane} out of range (batch {batch})");
    anyhow::ensure!(
        src.tensors.len() == tensors.len(),
        "lane state arity {} != decoder state arity {}",
        src.tensors.len(),
        tensors.len()
    );
    for (i, (t, s)) in tensors.iter_mut().zip(&src.tensors).enumerate() {
        anyhow::ensure!(
            !t.shape.is_empty() && t.shape[0] == batch && t.shape[1..] == s.shape[..],
            "lane state tensor {i} shape {:?} does not fit decoder state {:?}",
            s.shape,
            t.shape
        );
        let n = t.numel() / batch;
        match (&mut t.data, &s.data) {
            (Data::F32(d), Data::F32(v)) => {
                d[lane * n..(lane + 1) * n].copy_from_slice(v)
            }
            (Data::I32(d), Data::I32(v)) => {
                d[lane * n..(lane + 1) * n].copy_from_slice(v)
            }
            _ => anyhow::bail!("lane state tensor {i} dtype mismatch"),
        }
    }
    Ok(())
}

/// Zero lane `lane` of each (B, ...)-shaped state tensor in place.
pub fn zero_lane_slices(tensors: &mut [Tensor], batch: usize, lane: usize) -> Result<()> {
    anyhow::ensure!(lane < batch, "lane {lane} out of range (batch {batch})");
    for (i, t) in tensors.iter_mut().enumerate() {
        anyhow::ensure!(
            !t.shape.is_empty() && t.shape[0] == batch,
            "state tensor {i} ({:?}) is not lane-separable over batch {batch}",
            t.shape
        );
        let n = t.numel() / batch;
        match &mut t.data {
            Data::F32(v) => v[lane * n..(lane + 1) * n].fill(0.0),
            Data::I32(v) => v[lane * n..(lane + 1) * n].fill(0),
        }
    }
    Ok(())
}

/// Batched autoregressive step function with per-lane state check-in/out:
/// the contract between decode backends (PJRT artifacts or the pure-Rust
/// reference models) and the continuous-batching serving engine.
///
/// Per-lane computation must be lane-independent: a lane's logits depend
/// only on that lane's state, token, and position, so a request's token
/// stream is bitwise identical whichever batch its lanes ride in.
pub trait Decoder {
    /// Fixed decode width (number of batch lanes).
    fn lanes(&self) -> usize;

    /// One step for all lanes: `tokens` (B,) i32, per-lane positions;
    /// returns logits (B, V).  Idle lanes feed a pad token and pos 0;
    /// their rows are ignored by the caller.
    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor>;

    /// Check lane `lane`'s recurrent state out into `out` (buffer reused).
    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()>;

    /// Check a saved state back into lane `lane`.
    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()>;

    /// Zero lane `lane` (fresh request; no copy).
    fn reset_lane(&mut self, lane: usize) -> Result<()>;

    /// Modeled bytes of one lane's recurrent state when that lane is at
    /// position `pos` (constant for LSM; staircase for attention KV).
    fn lane_state_bytes(&self, pos: usize) -> usize;

    /// True when every live lane must sit at the same position each step
    /// (the scalar-pos PJRT attention artifacts).  Ragged serving --
    /// staggered admission, preemption, mixed request lengths -- is
    /// impossible on such a backend with more than one lane, so the
    /// engine rejects the combination at construction with a typed
    /// `EngineError::AlignedLanesOnly` instead of failing mid-trace.
    fn aligned_lanes_only(&self) -> bool {
        false
    }
}

/// Pure-LSM decoder: one artifact, constant state.
pub struct LsmDecoder {
    pub batch: usize,
    exe: Rc<Executable>,
    params: Bundle,
    state: DecodeState,
    pub var: Variant,
}

impl LsmDecoder {
    pub fn new(rt: &Runtime, tag: &str, batch: usize) -> Result<Self> {
        let exe = rt.load(&format!("decode_{tag}_b{batch}"))?;
        let params = rt.init_params(tag, 0)?;
        let var = rt.manifest.variant(tag)?.clone();
        let state = init_state(&exe.spec, params.tensors.len());
        Ok(LsmDecoder { batch, exe, params, state, var })
    }

    pub fn with_params(mut self, params: Bundle) -> Self {
        self.params = params;
        self
    }

    /// One step: feed `token` (B,) at position `pos`, return logits (B, V).
    pub fn step(&mut self, token: &Tensor, pos: i32) -> Result<Tensor> {
        let pos_t = Tensor::scalar_i32(pos);
        let mut args: Vec<&Tensor> = Vec::new();
        args.extend(self.params.tensors.iter());
        args.extend(self.state.tensors.iter());
        args.push(token);
        args.push(&pos_t);
        let mut out = self.exe.run(&args)?;
        let logits = out.remove(0);
        self.state.tensors = out;
        Ok(logits)
    }

    pub fn reset(&mut self) {
        self.state.reset();
    }

    pub fn state_bytes(&self) -> usize {
        self.state.size_bytes()
    }
}

impl Decoder for LsmDecoder {
    fn lanes(&self) -> usize {
        self.batch
    }

    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor> {
        anyhow::ensure!(pos.len() == self.batch, "pos len != batch");
        // The decode artifact takes one scalar step counter; the LSM
        // recurrence is position-invariant (all history lives in the
        // constant-size state), so the counter may run ahead for lanes
        // that joined the batch late.
        let p = pos.iter().copied().max().unwrap_or(0);
        self.step(tokens, p)
    }

    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()> {
        save_lane_slices(&self.state.tensors, self.batch, lane, out)
    }

    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()> {
        load_lane_slices(&mut self.state.tensors, self.batch, lane, src)
    }

    fn reset_lane(&mut self, lane: usize) -> Result<()> {
        zero_lane_slices(&mut self.state.tensors, self.batch, lane)
    }

    fn lane_state_bytes(&self, _pos: usize) -> usize {
        self.state.size_bytes() / self.batch
    }
}

/// Attention decoder with KV-cache staircase.
pub struct AttnDecoder {
    pub batch: usize,
    exes: Vec<(usize, Rc<Executable>)>,
    params: Bundle,
    state: DecodeState,
    cur: usize, // current staircase index
    pub var: Variant,
}

impl AttnDecoder {
    pub fn new(rt: &Runtime, tag: &str, batch: usize, sizes: &[usize]) -> Result<Self> {
        let mut exes = Vec::new();
        for &n in sizes {
            exes.push((n, rt.load(&format!("decode_{tag}_b{batch}_n{n}"))?));
        }
        let params = rt.init_params(tag, 0)?;
        let var = rt.manifest.variant(tag)?.clone();
        let state = init_state(&exes[0].1.spec, params.tensors.len());
        Ok(AttnDecoder {
            batch,
            exes,
            params,
            state,
            cur: 0,
            var,
        })
    }

    /// State leaf specs of staircase entry `idx`.
    fn state_specs(&self, idx: usize) -> &[LeafSpec] {
        let spec = &self.exes[idx].1.spec;
        let n_params = self.params.tensors.len();
        &spec.args[n_params..spec.args.len() - 2]
    }

    /// Grow the KV cache into the next staircase size, preserving dtype
    /// and copying history for every state tensor.
    fn grow_to(&mut self, idx: usize) -> Result<()> {
        let specs = self.state_specs(idx).to_vec();
        self.state.tensors = grow_state(&self.state.tensors, &specs)?;
        self.cur = idx;
        Ok(())
    }

    pub fn step(&mut self, token: &Tensor, pos: i32) -> Result<Tensor> {
        // grow staircase if pos exceeds the current cache
        while pos as usize >= self.exes[self.cur].0 {
            let next = self.cur + 1;
            anyhow::ensure!(next < self.exes.len(), "decode length exceeds staircase");
            self.grow_to(next)?;
        }
        let exe = self.exes[self.cur].1.clone();
        let pos_t = Tensor::scalar_i32(pos);
        let mut args: Vec<&Tensor> = Vec::new();
        args.extend(self.params.tensors.iter());
        args.extend(self.state.tensors.iter());
        args.push(token);
        args.push(&pos_t);
        let mut out = exe.run(&args)?;
        let logits = out.remove(0);
        self.state.tensors = out;
        Ok(logits)
    }

    pub fn state_bytes(&self) -> usize {
        self.state.size_bytes()
    }
}

impl Decoder for AttnDecoder {
    fn lanes(&self) -> usize {
        self.batch
    }

    fn decode_step(&mut self, tokens: &Tensor, pos: &[i32]) -> Result<Tensor> {
        anyhow::ensure!(pos.len() == self.batch, "pos len != batch");
        // The attention artifacts write KV row `pos` for the whole batch,
        // so continuous batching over PJRT attention requires aligned
        // lanes; the reference backend (`serve::refmodel`) lifts this.
        let p = pos[0];
        anyhow::ensure!(
            pos.iter().all(|&x| x == p),
            "AttnDecoder requires all lanes at the same position (scalar-pos artifact)"
        );
        self.step(tokens, p)
    }

    fn save_lane(&self, lane: usize, out: &mut LaneState) -> Result<()> {
        save_lane_slices(&self.state.tensors, self.batch, lane, out)
    }

    fn load_lane(&mut self, lane: usize, src: &LaneState) -> Result<()> {
        load_lane_slices(&mut self.state.tensors, self.batch, lane, src)
    }

    fn reset_lane(&mut self, lane: usize) -> Result<()> {
        zero_lane_slices(&mut self.state.tensors, self.batch, lane)
    }

    fn lane_state_bytes(&self, pos: usize) -> usize {
        let idx = self
            .exes
            .iter()
            .position(|(n, _)| pos < *n)
            .unwrap_or(self.exes.len() - 1);
        let bytes: usize = self.state_specs(idx).iter().map(|s| s.numel() * 4).sum();
        bytes / self.batch
    }

    /// The staircase artifacts write KV row `pos` for the whole batch
    /// (ROADMAP "Known gap"), so ragged serving is impossible here.
    fn aligned_lanes_only(&self) -> bool {
        true
    }
}

/// Greedy argmax over (B, V) logits -> (B,) tokens.  Ties break to the
/// first (lowest) index -- the serving sampler's greedy path matches.
pub fn greedy(logits: &Tensor) -> Result<Tensor> {
    let v = *logits.shape.last().unwrap();
    let b = logits.numel() / v;
    let data = logits.as_f32()?;
    let mut out = Vec::with_capacity(b);
    for r in 0..b {
        let row = &data[r * v..(r + 1) * v];
        let mut best = 0usize;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        out.push(best as i32);
    }
    Ok(Tensor::i32(&[b], out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: &str) -> LeafSpec {
        LeafSpec { path: String::new(), shape: shape.to_vec(), dtype: dtype.to_string() }
    }

    #[test]
    fn greedy_picks_argmax_rows() {
        let l = Tensor::f32(&[2, 3], vec![0.1, 0.9, 0.0, 2.0, -1.0, 1.0]);
        let g = greedy(&l).unwrap();
        assert_eq!(g.as_i32().unwrap(), &[1, 0]);
    }

    #[test]
    fn grow_state_copies_4d_cache_rows() {
        // (B=2, H=1, N=2, D=2) -> N=4: old rows land in front, zeros after
        let old = Tensor::f32(&[2, 1, 2, 2], (1..=8).map(|x| x as f32).collect());
        let grown = grow_state(&[old], &[spec(&[2, 1, 4, 2], "float32")]).unwrap();
        assert_eq!(grown[0].shape, vec![2, 1, 4, 2]);
        assert_eq!(
            grown[0].as_f32().unwrap(),
            &[1., 2., 3., 4., 0., 0., 0., 0., 5., 6., 7., 8., 0., 0., 0., 0.]
        );
    }

    #[test]
    fn grow_state_preserves_same_shape_int_state() {
        // regression: integer-typed and non-4D state tensors used to be
        // silently replaced with f32 zeros on staircase growth
        let pos = Tensor::i32(&[2], vec![7, 9]);
        let grown = grow_state(&[pos.clone()], &[spec(&[2], "int32")]).unwrap();
        assert_eq!(grown[0], pos);
    }

    #[test]
    fn grow_state_preserves_same_shape_non4d_f32() {
        let s = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let grown = grow_state(&[s.clone()], &[spec(&[2, 3], "float32")]).unwrap();
        assert_eq!(grown[0], s);
    }

    #[test]
    fn grow_state_grows_int_cache_with_dtype() {
        let old = Tensor::i32(&[2, 2], vec![1, 2, 3, 4]);
        let grown = grow_state(&[old], &[spec(&[2, 4], "int32")]).unwrap();
        assert!(!grown[0].is_f32(), "dtype must be preserved");
        assert_eq!(grown[0].as_i32().unwrap(), &[1, 2, 0, 0, 3, 4, 0, 0]);
    }

    #[test]
    fn grow_state_rejects_dtype_change() {
        let old = Tensor::i32(&[2], vec![1, 2]);
        assert!(grow_state(&[old], &[spec(&[4], "float32")]).is_err());
    }

    #[test]
    fn lane_slices_roundtrip() {
        let mut tensors = vec![
            Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::i32(&[2, 2], vec![10, 11, 12, 13]),
        ];
        let mut lane = LaneState::default();
        save_lane_slices(&tensors, 2, 1, &mut lane).unwrap();
        assert_eq!(lane.tensors[0].as_f32().unwrap(), &[4., 5., 6.]);
        assert_eq!(lane.tensors[1].as_i32().unwrap(), &[12, 13]);
        assert_eq!(lane.reallocs, 2);
        zero_lane_slices(&mut tensors, 2, 1).unwrap();
        assert_eq!(tensors[0].as_f32().unwrap(), &[1., 2., 3., 0., 0., 0.]);
        load_lane_slices(&mut tensors, 2, 1, &lane).unwrap();
        assert_eq!(tensors[0].as_f32().unwrap(), &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(tensors[1].as_i32().unwrap(), &[10, 11, 12, 13]);
        // steady state: a second save reuses the buffers
        save_lane_slices(&tensors, 2, 0, &mut lane).unwrap();
        assert_eq!(lane.reallocs, 2);
        assert_eq!(lane.tensors[0].as_f32().unwrap(), &[1., 2., 3.]);
    }

    #[test]
    fn decode_state_reset_zeroes_in_place() {
        let mut st = DecodeState {
            tensors: vec![
                Tensor::f32(&[2], vec![1., 2.]),
                Tensor::i32(&[2], vec![3, 4]),
            ],
        };
        st.reset();
        assert_eq!(st.tensors[0].as_f32().unwrap(), &[0., 0.]);
        assert_eq!(st.tensors[1].as_i32().unwrap(), &[0, 0]);
    }
}
