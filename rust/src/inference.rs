//! Inference driver (paper Fig. 5): autoregressive decoding from Rust over
//! the `decode_*` artifacts.
//!
//! Linear-MoE models carry one constant-size (Dk, Dv) state per head per
//! layer -> constant per-token latency and memory.  The attention Baseline
//! carries a KV cache; we allocate it as a power-of-two **staircase**
//! (decode_..._n{128,256,...} artifacts): step t runs the smallest cache
//! >= t, mirroring how paged/banded serving systems grow the cache, and
//! giving per-token cost that grows with position -- the Fig. 5 contrast.

use anyhow::Result;
use std::rc::Rc;

use crate::runtime::{Executable, Runtime, Variant};
use crate::tensor::{Bundle, Tensor};

pub struct DecodeStats {
    pub tokens: usize,
    pub secs: f64,
    /// modeled state bytes at the final position (memcost)
    pub state_bytes: usize,
}

/// Decode state for one model: per-layer tensors in manifest order.
pub struct DecodeState {
    pub tensors: Vec<Tensor>,
}

fn init_state(var: &Variant, spec: &crate::runtime::ArtifactSpec, n_params: usize) -> DecodeState {
    // state leaves sit between params and (token, pos) in the arg list.
    let n_args = spec.args.len();
    let state_specs = &spec.args[n_params..n_args - 2];
    let tensors = state_specs
        .iter()
        .map(|s| {
            if s.dtype.contains("int") {
                Tensor::i32(&s.shape, vec![0; s.numel()])
            } else {
                Tensor::zeros(&s.shape)
            }
        })
        .collect();
    let _ = var;
    DecodeState { tensors }
}

/// Pure-LSM decoder: one artifact, constant state.
pub struct LsmDecoder {
    pub batch: usize,
    exe: Rc<Executable>,
    params: Bundle,
    state: DecodeState,
    pub var: Variant,
}

impl LsmDecoder {
    pub fn new(rt: &Runtime, tag: &str, batch: usize) -> Result<Self> {
        let exe = rt.load(&format!("decode_{tag}_b{batch}"))?;
        let params = rt.init_params(tag, 0)?;
        let var = rt.manifest.variant(tag)?.clone();
        let state = init_state(&var, &exe.spec, params.tensors.len());
        Ok(LsmDecoder { batch, exe, params, state, var })
    }

    pub fn with_params(mut self, params: Bundle) -> Self {
        self.params = params;
        self
    }

    /// One step: feed `token` (B,) at position `pos`, return logits (B, V).
    pub fn step(&mut self, token: &Tensor, pos: i32) -> Result<Tensor> {
        let pos_t = Tensor::scalar_i32(pos);
        let mut args: Vec<&Tensor> = Vec::new();
        args.extend(self.params.tensors.iter());
        args.extend(self.state.tensors.iter());
        args.push(token);
        args.push(&pos_t);
        let mut out = self.exe.run(&args)?;
        let logits = out.remove(0);
        self.state.tensors = out;
        Ok(logits)
    }

    pub fn reset(&mut self) {
        for t in &mut self.state.tensors {
            *t = if t.is_f32() {
                Tensor::zeros(&t.shape)
            } else {
                Tensor::i32(&t.shape, vec![0; t.numel()])
            };
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.state.tensors.iter().map(|t| t.size_bytes()).sum()
    }
}

/// Attention decoder with KV-cache staircase.
pub struct AttnDecoder {
    pub batch: usize,
    exes: Vec<(usize, Rc<Executable>)>,
    params: Bundle,
    state: DecodeState,
    cur: usize, // current staircase index
    pub var: Variant,
}

impl AttnDecoder {
    pub fn new(rt: &Runtime, tag: &str, batch: usize, sizes: &[usize]) -> Result<Self> {
        let mut exes = Vec::new();
        for &n in sizes {
            exes.push((n, rt.load(&format!("decode_{tag}_b{batch}_n{n}"))?));
        }
        let params = rt.init_params(tag, 0)?;
        let var = rt.manifest.variant(tag)?.clone();
        let state = init_state(&var, &exes[0].1.spec, params.tensors.len());
        Ok(AttnDecoder {
            batch,
            exes,
            params,
            state,
            cur: 0,
            var,
        })
    }

    /// Grow the KV cache into the next staircase size, copying history.
    fn grow_to(&mut self, idx: usize) {
        let (new_n, exe) = &self.exes[idx];
        let spec = &exe.spec;
        let n_params = self.params.tensors.len();
        let state_specs = &spec.args[n_params..spec.args.len() - 2];
        let mut new_tensors = Vec::with_capacity(self.state.tensors.len());
        for (old, s) in self.state.tensors.iter().zip(state_specs) {
            // caches are (B, H, N, Dh): copy old rows into the front.
            let mut t = Tensor::zeros(&s.shape);
            if old.shape.len() == 4 && s.shape.len() == 4 {
                let (b, h, n_old, d) =
                    (old.shape[0], old.shape[1], old.shape[2], old.shape[3]);
                let n_new = s.shape[2];
                let src = old.as_f32().unwrap();
                let dst = t.as_f32_mut().unwrap();
                for bi in 0..b * h {
                    for r in 0..n_old.min(n_new) {
                        let so = (bi * n_old + r) * d;
                        let dofs = (bi * n_new + r) * d;
                        dst[dofs..dofs + d].copy_from_slice(&src[so..so + d]);
                    }
                }
            }
            new_tensors.push(t);
        }
        self.state.tensors = new_tensors;
        self.cur = idx;
        let _ = new_n;
    }

    pub fn step(&mut self, token: &Tensor, pos: i32) -> Result<Tensor> {
        // grow staircase if pos exceeds the current cache
        while pos as usize >= self.exes[self.cur].0 {
            let next = self.cur + 1;
            anyhow::ensure!(next < self.exes.len(), "decode length exceeds staircase");
            self.grow_to(next);
        }
        let exe = self.exes[self.cur].1.clone();
        let pos_t = Tensor::scalar_i32(pos);
        let mut args: Vec<&Tensor> = Vec::new();
        args.extend(self.params.tensors.iter());
        args.extend(self.state.tensors.iter());
        args.push(token);
        args.push(&pos_t);
        let mut out = exe.run(&args)?;
        let logits = out.remove(0);
        self.state.tensors = out;
        Ok(logits)
    }

    pub fn state_bytes(&self) -> usize {
        self.state.tensors.iter().map(|t| t.size_bytes()).sum()
    }
}

/// Greedy argmax over (B, V) logits -> (B,) tokens.
pub fn greedy(logits: &Tensor) -> Result<Tensor> {
    let v = *logits.shape.last().unwrap();
    let b = logits.numel() / v;
    let data = logits.as_f32()?;
    let mut out = Vec::with_capacity(b);
    for r in 0..b {
        let row = &data[r * v..(r + 1) * v];
        let mut best = 0usize;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        out.push(best as i32);
    }
    Ok(Tensor::i32(&[b], out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax_rows() {
        let l = Tensor::f32(&[2, 3], vec![0.1, 0.9, 0.0, 2.0, -1.0, 1.0]);
        let g = greedy(&l).unwrap();
        assert_eq!(g.as_i32().unwrap(), &[1, 0]);
    }
}
