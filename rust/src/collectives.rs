//! In-process collective communication library.
//!
//! The paper's cluster is 8 GPUs over NCCL; here a "device" is a worker
//! thread and the transport is shared memory, but the *algorithms* are the
//! same: the LASP AllGather of d×d memory states (paper Alg. 1/2), the
//! TP all-reduce decomposed as all-gather + reduce-scatter (paper §A.2),
//! the EP all-to-all token exchange, and ring point-to-point for LASP-1.
//! Per-handle traffic metering lets benches *measure* the paper's
//! communication-volume claims instead of asserting them.
//!
//! Synchronization: a generation-counted exchange board (deposit slots +
//! condvar).  All ranks must issue collectives in the same program order
//! (standard SPMD contract).
//!
//! Fault tolerance: every collective carries a configurable deadline
//! (`CommCfg::timeout`).  A rank that waits past its deadline **poisons**
//! the board and returns [`CommError::Timeout`]; every peer's pending or
//! subsequent collective then fails fast with [`CommError::PeerFailed`]
//! instead of hanging forever.  A [`FaultPlan`](crate::fault::FaultPlan)
//! threaded into every `CommHandle` lets tests and the `--fault` CLI flag
//! inject rank kills, stragglers, and dropped ring messages
//! deterministically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::fault::{Fault, FaultPlan};
use crate::tensor::Tensor;

/// Default collective deadline.  Generous for in-process transports; the
/// CLI / tests lower it via [`CommCfg`].
pub const DEFAULT_COMM_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// Typed communication errors.
// ---------------------------------------------------------------------------

/// Why a collective failed.  `anyhow`-compatible, so coordinator code can
/// `?` it while supervisors downcast to decide on recovery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommError {
    /// This rank waited past its configured deadline.  The board has been
    /// poisoned on this rank's behalf so peers fail fast.
    Timeout { op: &'static str, rank: usize, waited_ms: u64 },
    /// Rank `rank` declared the group failed (it timed out, was killed by
    /// an injected fault, or panicked inside a collective).
    PeerFailed { rank: usize },
    /// This rank already poisoned the group; further ops are rejected.
    Poisoned,
    /// Ring channel disconnected: the neighbour thread exited.
    Disconnected { op: &'static str },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { op, rank, waited_ms } => {
                write!(f, "collective {op} timed out on rank {rank} after {waited_ms} ms")
            }
            CommError::PeerFailed { rank } => {
                write!(f, "collective aborted: rank {rank} failed")
            }
            CommError::Poisoned => write!(f, "communicator is poisoned"),
            CommError::Disconnected { op } => {
                write!(f, "{op}: ring neighbour disconnected")
            }
        }
    }
}

impl std::error::Error for CommError {}

fn poison_err(self_rank: usize, by: usize) -> CommError {
    if by == self_rank {
        CommError::Poisoned
    } else {
        CommError::PeerFailed { rank: by }
    }
}

// ---------------------------------------------------------------------------
// Generic rendezvous board.
// ---------------------------------------------------------------------------

struct BoardState<T> {
    gen: u64,
    filled: usize,
    drained: usize,
    vals: Vec<Option<Arc<T>>>,
    /// Some(rank) once rank has declared the group failed.
    poisoned: Option<usize>,
}

pub struct Exchange<T> {
    state: Mutex<BoardState<T>>,
    cv: Condvar,
    world: usize,
}

impl<T> Exchange<T> {
    pub fn new(world: usize) -> Self {
        Exchange {
            state: Mutex::new(BoardState {
                gen: 0,
                filled: 0,
                drained: 0,
                vals: (0..world).map(|_| None).collect(),
                poisoned: None,
            }),
            cv: Condvar::new(),
            world,
        }
    }

    /// Declare the group failed on behalf of `rank`: wake every waiter and
    /// make all pending and future exchanges fail fast.  First writer wins.
    pub fn poison(&self, rank: usize) {
        let mut st = self.state.lock().unwrap();
        if st.poisoned.is_none() {
            st.poisoned = Some(rank);
        }
        self.cv.notify_all();
    }

    pub fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned.is_some()
    }

    fn wait_or_deadline<'a>(
        &self,
        st: MutexGuard<'a, BoardState<T>>,
        deadline: Instant,
        rank: usize,
        op: &'static str,
        timeout: Duration,
    ) -> Result<MutexGuard<'a, BoardState<T>>, CommError> {
        let now = Instant::now();
        if now >= deadline {
            let mut st = st;
            if st.poisoned.is_none() {
                st.poisoned = Some(rank);
            }
            self.cv.notify_all();
            return Err(CommError::Timeout {
                op,
                rank,
                waited_ms: timeout.as_millis() as u64,
            });
        }
        let (st, _timed_out) = self.cv.wait_timeout(st, deadline - now).unwrap();
        Ok(st)
    }

    /// Deposit this rank's value; block until every rank has deposited or
    /// `timeout` elapses; return all values (rank order).  Reusable across
    /// rounds.  On deadline the caller poisons the board (peers fail fast
    /// with `PeerFailed`); on an already-poisoned board the op is rejected
    /// immediately.
    pub fn exchange_deadline(
        &self,
        rank: usize,
        val: T,
        timeout: Duration,
        op: &'static str,
    ) -> Result<Vec<Arc<T>>, CommError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        // Wait for our slot from the previous round to be fully drained.
        loop {
            if let Some(by) = st.poisoned {
                return Err(poison_err(rank, by));
            }
            if st.vals[rank].is_none() {
                break;
            }
            st = self.wait_or_deadline(st, deadline, rank, op, timeout)?;
        }
        st.vals[rank] = Some(Arc::new(val));
        st.filled += 1;
        let my_gen = st.gen;
        if st.filled == self.world {
            self.cv.notify_all();
        }
        // Wait until every rank of this generation has deposited.
        while st.gen == my_gen && st.filled < self.world {
            if let Some(by) = st.poisoned {
                return Err(poison_err(rank, by));
            }
            st = self.wait_or_deadline(st, deadline, rank, op, timeout)?;
        }
        if let Some(by) = st.poisoned {
            return Err(poison_err(rank, by));
        }
        let out: Vec<Arc<T>> = st.vals.iter().map(|v| v.clone().unwrap()).collect();
        st.drained += 1;
        if st.drained == self.world {
            for v in st.vals.iter_mut() {
                *v = None;
            }
            st.filled = 0;
            st.drained = 0;
            st.gen += 1;
            self.cv.notify_all();
        }
        Ok(out)
    }

    /// Back-compat convenience with the default deadline.
    pub fn exchange(&self, rank: usize, val: T) -> Result<Vec<Arc<T>>, CommError> {
        self.exchange_deadline(rank, val, DEFAULT_COMM_TIMEOUT, "exchange")
    }
}

// ---------------------------------------------------------------------------
// Process group.
// ---------------------------------------------------------------------------

/// Communicator configuration: collective deadline + fault-injection plan.
#[derive(Clone)]
pub struct CommCfg {
    pub timeout: Duration,
    pub faults: Arc<FaultPlan>,
}

impl Default for CommCfg {
    fn default() -> Self {
        CommCfg { timeout: DEFAULT_COMM_TIMEOUT, faults: Arc::new(FaultPlan::none()) }
    }
}

/// Counters for observed / injected failures (group-wide totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommFaultStats {
    pub timeouts: u64,
    pub peer_failures: u64,
    pub injected_kills: u64,
    pub injected_delays: u64,
    pub dropped_ring: u64,
}

impl CommFaultStats {
    /// Accumulate another group's counters (the resilient trainer builds a
    /// fresh communicator per attempt and sums their stats).
    pub fn merge(&mut self, o: CommFaultStats) {
        self.timeouts += o.timeouts;
        self.peer_failures += o.peer_failures;
        self.injected_kills += o.injected_kills;
        self.injected_delays += o.injected_delays;
        self.dropped_ring += o.dropped_ring;
    }
}

struct Shared {
    board: Exchange<Tensor>,
    board_multi: Exchange<Vec<Tensor>>,
    /// logical bytes moved across the group (sum over ranks of bytes each
    /// rank contributed to the wire), per op class
    bytes_ag: AtomicU64,
    bytes_rs: AtomicU64,
    bytes_p2p: AtomicU64,
    bytes_a2a: AtomicU64,
    // fault observability
    timeouts: AtomicU64,
    peer_failures: AtomicU64,
    injected_kills: AtomicU64,
    injected_delays: AtomicU64,
    dropped_ring: AtomicU64,
}

/// A communicator over `world` ranks.  Clone-free: call `handles()` once
/// and move each `CommHandle` into its worker thread.
pub struct Comm {
    world: usize,
    shared: Arc<Shared>,
}

pub struct CommHandle {
    pub rank: usize,
    pub world: usize,
    shared: Arc<Shared>,
    ring_tx: Sender<Tensor>,
    ring_rx: Mutex<Receiver<Tensor>>,
    timeout: Duration,
    faults: Arc<FaultPlan>,
    /// current training step, set by the worker loop so faults addressed
    /// by (rank, step) can match
    step: AtomicU64,
}

impl Comm {
    pub fn new(world: usize) -> (Comm, Vec<CommHandle>) {
        Comm::new_with(world, CommCfg::default())
    }

    pub fn new_with(world: usize, cfg: CommCfg) -> (Comm, Vec<CommHandle>) {
        let shared = Arc::new(Shared {
            board: Exchange::new(world),
            board_multi: Exchange::new(world),
            bytes_ag: AtomicU64::new(0),
            bytes_rs: AtomicU64::new(0),
            bytes_p2p: AtomicU64::new(0),
            bytes_a2a: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            peer_failures: AtomicU64::new(0),
            injected_kills: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            dropped_ring: AtomicU64::new(0),
        });
        // ring edges: rank i sends to (i+1) % world
        let mut txs = Vec::with_capacity(world);
        let mut rxs = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        // handle[i] receives on channel i (fed by rank i-1) and sends on
        // channel (i+1) % world.
        let mut handles = Vec::with_capacity(world);
        for rank in 0..world {
            handles.push(CommHandle {
                rank,
                world,
                shared: shared.clone(),
                ring_tx: txs[(rank + 1) % world].clone(),
                ring_rx: Mutex::new(rxs[rank].take().unwrap()),
                timeout: cfg.timeout,
                faults: cfg.faults.clone(),
                step: AtomicU64::new(0),
            });
        }
        (Comm { world, shared }, handles)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// (all-gather, reduce-scatter, p2p, all-to-all) logical bytes so far.
    pub fn traffic(&self) -> (u64, u64, u64, u64) {
        (
            self.shared.bytes_ag.load(Ordering::Relaxed),
            self.shared.bytes_rs.load(Ordering::Relaxed),
            self.shared.bytes_p2p.load(Ordering::Relaxed),
            self.shared.bytes_a2a.load(Ordering::Relaxed),
        )
    }

    /// Failure counters accumulated by the group's handles.
    pub fn fault_stats(&self) -> CommFaultStats {
        CommFaultStats {
            timeouts: self.shared.timeouts.load(Ordering::Relaxed),
            peer_failures: self.shared.peer_failures.load(Ordering::Relaxed),
            injected_kills: self.shared.injected_kills.load(Ordering::Relaxed),
            injected_delays: self.shared.injected_delays.load(Ordering::Relaxed),
            dropped_ring: self.shared.dropped_ring.load(Ordering::Relaxed),
        }
    }

    /// True once any rank has poisoned either exchange board.
    pub fn is_poisoned(&self) -> bool {
        self.shared.board.is_poisoned() || self.shared.board_multi.is_poisoned()
    }
}

impl CommHandle {
    /// Record the current training step so (rank, step)-addressed faults
    /// can match.  Called once per step by worker loops.
    pub fn set_step(&self, step: usize) {
        self.step.store(step as u64, Ordering::Relaxed);
    }

    pub fn cur_step(&self) -> usize {
        self.step.load(Ordering::Relaxed) as usize
    }

    /// Consult the fault plan on entry to a collective.  Delays sleep here;
    /// kills poison both boards (so peers fail fast with `PeerFailed`)
    /// and then panic, modelling a hard rank death.
    fn preflight(&self, op: &'static str) {
        match self.faults.take_collective(self.rank, self.cur_step()) {
            Some(Fault::DelayCollective { ms, .. }) => {
                self.shared.injected_delays.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some(Fault::KillRank { rank, step }) => {
                self.shared.injected_kills.fetch_add(1, Ordering::Relaxed);
                self.shared.board.poison(rank);
                self.shared.board_multi.poison(rank);
                panic!("injected fault: kill rank {rank} at step {step} (in {op})");
            }
            _ => {}
        }
    }

    fn record_err(&self, e: &CommError) {
        match e {
            CommError::Timeout { .. } => {
                self.shared.timeouts.fetch_add(1, Ordering::Relaxed);
            }
            CommError::PeerFailed { .. } => {
                self.shared.peer_failures.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    fn board_exchange(
        &self,
        val: Tensor,
        op: &'static str,
    ) -> Result<Vec<Arc<Tensor>>, CommError> {
        self.preflight(op);
        self.shared
            .board
            .exchange_deadline(self.rank, val, self.timeout, op)
            .map_err(|e| {
                self.record_err(&e);
                e
            })
    }

    pub fn barrier(&self) -> Result<(), CommError> {
        self.board_exchange(Tensor::scalar_i32(0), "barrier")?;
        Ok(())
    }

    /// All-gather: returns every rank's tensor in rank order.  This is the
    /// LASP-2 primitive (paper §2.2.1): one collective on the memory state.
    pub fn all_gather(&self, local: Tensor) -> Result<Vec<Arc<Tensor>>, CommError> {
        self.shared
            .bytes_ag
            .fetch_add(local.size_bytes() as u64, Ordering::Relaxed);
        self.board_exchange(local, "all_gather")
    }

    /// Reduce-scatter (sum): every rank contributes a full-length tensor,
    /// receives the sum of its 1/world shard.  Length must divide evenly.
    pub fn reduce_scatter_sum(&self, local: Tensor) -> Result<Tensor> {
        let n = local.numel();
        anyhow::ensure!(n % self.world == 0,
                        "reduce_scatter: {n} not divisible by world {}", self.world);
        self.shared
            .bytes_rs
            .fetch_add(local.size_bytes() as u64, Ordering::Relaxed);
        let shard = n / self.world;
        let all = self.board_exchange(local, "reduce_scatter")?;
        let lo = self.rank * shard;
        let mut out = vec![0f32; shard];
        for t in &all {
            let v = t.as_f32()?;
            for (o, x) in out.iter_mut().zip(&v[lo..lo + shard]) {
                *o += *x;
            }
        }
        Ok(Tensor::f32(&[shard], out))
    }

    /// All-reduce (sum), decomposed as all-gather + local reduction --
    /// functionally the AG+RS decomposition of paper §A.2.
    pub fn all_reduce_sum(&self, local: Tensor) -> Result<Tensor> {
        let shape = local.shape.clone();
        let all = self.all_gather(local)?;
        let mut out = vec![0f32; shape.iter().product()];
        for t in &all {
            let v = t.as_f32()?;
            for (o, x) in out.iter_mut().zip(v) {
                *o += *x;
            }
        }
        Ok(Tensor::f32(&shape, out))
    }

    /// Broadcast from `root`.
    pub fn broadcast(&self, root: usize, local: Tensor) -> Result<Arc<Tensor>, CommError> {
        let all = self.board_exchange(local, "broadcast")?;
        Ok(all[root].clone())
    }

    /// Ring point-to-point: send to (rank+1) % world, receive from
    /// (rank-1) % world.  This is LASP-1's communication pattern.
    pub fn ring_shift(&self, send: Tensor) -> Result<Tensor> {
        self.ring_send(send)?;
        self.ring_recv()
    }

    /// Asynchronous ring send to (rank+1) % world (used by the LASP-1
    /// sequential prefix chain, where only a neighbour pair synchronizes).
    /// An injected `DropRing` fault discards the message (the receiver's
    /// deadline then fires).
    pub fn ring_send(&self, send: Tensor) -> Result<()> {
        self.preflight("ring_send");
        if self.faults.take_drop_ring(self.rank, self.cur_step()).is_some() {
            self.shared.dropped_ring.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.shared
            .bytes_p2p
            .fetch_add(send.size_bytes() as u64, Ordering::Relaxed);
        self.ring_tx
            .send(send)
            .map_err(|_| CommError::Disconnected { op: "ring_send" })?;
        Ok(())
    }

    /// Ring receive from (rank-1) % world with the configured deadline.
    /// A deadline poisons the boards (the ring and board collectives share
    /// fate: a dead neighbour breaks both).
    pub fn ring_recv(&self) -> Result<Tensor> {
        self.preflight("ring_recv");
        match self.ring_rx.lock().unwrap().recv_timeout(self.timeout) {
            Ok(t) => Ok(t),
            Err(RecvTimeoutError::Timeout) => {
                let e = CommError::Timeout {
                    op: "ring_recv",
                    rank: self.rank,
                    waited_ms: self.timeout.as_millis() as u64,
                };
                self.record_err(&e);
                self.shared.board.poison(self.rank);
                self.shared.board_multi.poison(self.rank);
                Err(e.into())
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(CommError::Disconnected { op: "ring_recv" }.into())
            }
        }
    }

    /// All-to-all: `parts[d]` goes to rank d; returns what every rank sent
    /// to us (rank order).  The EP token-exchange primitive.
    pub fn all_to_all(&self, parts: Vec<Tensor>) -> Result<Vec<Tensor>> {
        anyhow::ensure!(parts.len() == self.world);
        let bytes: usize = parts.iter().map(|t| t.size_bytes()).sum();
        self.shared
            .bytes_a2a
            .fetch_add(bytes as u64, Ordering::Relaxed);
        self.preflight("all_to_all");
        let all = self
            .shared
            .board_multi
            .exchange_deadline(self.rank, parts, self.timeout, "all_to_all")
            .map_err(|e| {
                self.record_err(&e);
                e
            })?;
        Ok(all.iter().map(|v| v[self.rank].clone()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F, R>(world: usize, f: F) -> Vec<R>
    where
        F: Fn(CommHandle) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let (_comm, handles) = Comm::new(world);
        let f = Arc::new(f);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| {
                let f = f.clone();
                thread::spawn(move || f(h))
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let outs = run_world(4, |h| {
            let t = Tensor::f32(&[2], vec![h.rank as f32, 1.0]);
            let all = h.all_gather(t).unwrap();
            all.iter().map(|t| t.as_f32().unwrap()[0]).collect::<Vec<_>>()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let outs = run_world(3, |h| {
            let t = Tensor::f32(&[3], vec![1.0, h.rank as f32, 2.0]);
            h.all_reduce_sum(t).unwrap().as_f32().unwrap().to_vec()
        });
        for o in outs {
            assert_eq!(o, vec![3.0, 3.0, 6.0]);
        }
    }

    #[test]
    fn reduce_scatter_shards() {
        let outs = run_world(2, |h| {
            let t = Tensor::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]);
            let s = h.reduce_scatter_sum(t).unwrap();
            (h.rank, s.as_f32().unwrap().to_vec())
        });
        for (rank, o) in outs {
            if rank == 0 {
                assert_eq!(o, vec![2.0, 4.0]);
            } else {
                assert_eq!(o, vec![6.0, 8.0]);
            }
        }
    }

    #[test]
    fn ring_shift_rotates() {
        let outs = run_world(4, |h| {
            let t = Tensor::scalar_f32(h.rank as f32);
            let r = h.ring_shift(t).unwrap();
            (h.rank, r.item_f32().unwrap())
        });
        for (rank, v) in outs {
            assert_eq!(v as usize, (rank + 3) % 4);
        }
    }

    #[test]
    fn all_to_all_transposes() {
        let outs = run_world(3, |h| {
            let parts = (0..3)
                .map(|d| Tensor::scalar_f32((h.rank * 10 + d) as f32))
                .collect();
            let got = h.all_to_all(parts).unwrap();
            (h.rank, got.iter().map(|t| t.item_f32().unwrap()).collect::<Vec<_>>())
        });
        for (rank, v) in outs {
            // from rank s we receive s*10 + rank
            let want: Vec<f32> = (0..3).map(|s| (s * 10 + rank) as f32).collect();
            assert_eq!(v, want);
        }
    }

    #[test]
    fn board_reusable_many_rounds() {
        let outs = run_world(4, |h| {
            let mut acc = 0.0;
            for round in 0..50 {
                let t = Tensor::scalar_f32((h.rank + round) as f32);
                acc += h.all_reduce_sum(t).unwrap().item_f32().unwrap();
            }
            acc
        });
        let want: f32 = (0..50).map(|r| (6 + 4 * r) as f32).sum();
        for o in outs {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn timeout_fires_when_peer_never_arrives() {
        let cfg = CommCfg { timeout: Duration::from_millis(50), ..Default::default() };
        let (comm, mut handles) = Comm::new_with(2, cfg);
        let h0 = handles.remove(0);
        // rank 1 never calls the collective
        let t0 = Instant::now();
        let err = h0.all_gather(Tensor::scalar_f32(0.0)).unwrap_err();
        assert!(matches!(err, CommError::Timeout { rank: 0, .. }), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "must not block forever");
        assert!(comm.is_poisoned());
        assert_eq!(comm.fault_stats().timeouts, 1);
    }

    #[test]
    fn poisoned_board_rejects_subsequent_ops() {
        let cfg = CommCfg { timeout: Duration::from_millis(20), ..Default::default() };
        let (_comm, mut handles) = Comm::new_with(2, cfg);
        let h1 = handles.remove(1);
        let h0 = handles.remove(0);
        let _ = h0.all_gather(Tensor::scalar_f32(0.0)).unwrap_err(); // poisons
        // the late peer is told rank 0 failed, immediately
        let t0 = Instant::now();
        let err = h1.all_gather(Tensor::scalar_f32(1.0)).unwrap_err();
        assert_eq!(err, CommError::PeerFailed { rank: 0 });
        assert!(t0.elapsed() < Duration::from_millis(20));
        // and the poisoner itself is told the group is dead
        let err = h0.barrier().unwrap_err();
        assert_eq!(err, CommError::Poisoned);
    }

    #[test]
    fn injected_delay_slows_but_completes() {
        let faults = Arc::new(FaultPlan::parse("delay:rank=0,step=0,ms=30").unwrap());
        let cfg = CommCfg { timeout: Duration::from_secs(5), faults };
        let (comm, handles) = Comm::new_with(2, cfg);
        let t0 = Instant::now();
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| thread::spawn(move || h.all_reduce_sum(Tensor::scalar_f32(1.0)).unwrap()))
            .collect();
        for j in joins {
            assert_eq!(j.join().unwrap().item_f32().unwrap(), 2.0);
        }
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(comm.fault_stats().injected_delays, 1);
    }

    #[test]
    fn injected_kill_panics_rank_and_fails_peers_fast() {
        let faults = Arc::new(FaultPlan::parse("kill:rank=1,step=0").unwrap());
        let cfg = CommCfg { timeout: Duration::from_secs(30), faults };
        let (comm, handles) = Comm::new_with(2, cfg);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| thread::spawn(move || h.all_gather(Tensor::scalar_f32(0.0)).map(|_| ())))
            .collect();
        let t0 = Instant::now();
        let results: Vec<_> = joins.into_iter().map(|j| j.join()).collect();
        // rank 0: clean CommError; rank 1: panicked
        assert_eq!(
            results[0].as_ref().unwrap().unwrap_err(),
            CommError::PeerFailed { rank: 1 }
        );
        assert!(results[1].is_err(), "rank 1 must have panicked");
        // peers failed fast -- nowhere near the 30 s deadline
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(comm.fault_stats().injected_kills, 1);
    }

    #[test]
    fn dropped_ring_message_times_out_receiver() {
        let faults = Arc::new(FaultPlan::parse("drop_ring:rank=0,step=0").unwrap());
        let cfg = CommCfg { timeout: Duration::from_millis(50), faults };
        let (comm, mut handles) = Comm::new_with(2, cfg);
        let h1 = handles.remove(1);
        let h0 = handles.remove(0);
        h0.ring_send(Tensor::scalar_f32(7.0)).unwrap(); // dropped
        let err = h1.ring_recv().unwrap_err();
        let ce = err.downcast_ref::<CommError>().unwrap();
        assert!(matches!(ce, CommError::Timeout { op: "ring_recv", rank: 1, .. }), "{ce}");
        assert_eq!(comm.fault_stats().dropped_ring, 1);
        assert!(comm.is_poisoned());
    }
}
