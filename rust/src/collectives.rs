//! In-process collective communication library.
//!
//! The paper's cluster is 8 GPUs over NCCL; here a "device" is a worker
//! thread and the transport is shared memory, but the *algorithms* are the
//! same: the LASP AllGather of d×d memory states (paper Alg. 1/2), the
//! TP all-reduce decomposed as all-gather + reduce-scatter (paper §A.2),
//! the EP all-to-all token exchange, and ring point-to-point for LASP-1.
//! Per-handle traffic metering lets benches *measure* the paper's
//! communication-volume claims instead of asserting them.
//!
//! Synchronization: a generation-counted exchange board (deposit slots +
//! condvar).  All ranks must issue collectives in the same program order
//! (standard SPMD contract).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Generic rendezvous board.
// ---------------------------------------------------------------------------

struct BoardState<T> {
    gen: u64,
    filled: usize,
    drained: usize,
    vals: Vec<Option<Arc<T>>>,
}

pub struct Exchange<T> {
    state: Mutex<BoardState<T>>,
    cv: Condvar,
    world: usize,
}

impl<T> Exchange<T> {
    pub fn new(world: usize) -> Self {
        Exchange {
            state: Mutex::new(BoardState {
                gen: 0,
                filled: 0,
                drained: 0,
                vals: (0..world).map(|_| None).collect(),
            }),
            cv: Condvar::new(),
            world,
        }
    }

    /// Deposit this rank's value; block until every rank has deposited;
    /// return all values (rank order).  Reusable across rounds.
    pub fn exchange(&self, rank: usize, val: T) -> Vec<Arc<T>> {
        let mut st = self.state.lock().unwrap();
        // Wait for our slot from the previous round to be fully drained.
        while st.vals[rank].is_some() {
            st = self.cv.wait(st).unwrap();
        }
        st.vals[rank] = Some(Arc::new(val));
        st.filled += 1;
        let my_gen = st.gen;
        if st.filled == self.world {
            self.cv.notify_all();
        }
        while st.gen == my_gen && st.filled < self.world {
            st = self.cv.wait(st).unwrap();
        }
        let out: Vec<Arc<T>> = st.vals.iter().map(|v| v.clone().unwrap()).collect();
        st.drained += 1;
        if st.drained == self.world {
            for v in st.vals.iter_mut() {
                *v = None;
            }
            st.filled = 0;
            st.drained = 0;
            st.gen += 1;
            self.cv.notify_all();
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Process group.
// ---------------------------------------------------------------------------

struct Shared {
    board: Exchange<Tensor>,
    board_multi: Exchange<Vec<Tensor>>,
    /// logical bytes moved across the group (sum over ranks of bytes each
    /// rank contributed to the wire), per op class
    bytes_ag: AtomicU64,
    bytes_rs: AtomicU64,
    bytes_p2p: AtomicU64,
    bytes_a2a: AtomicU64,
}

/// A communicator over `world` ranks.  Clone-free: call `handles()` once
/// and move each `CommHandle` into its worker thread.
pub struct Comm {
    world: usize,
    shared: Arc<Shared>,
}

pub struct CommHandle {
    pub rank: usize,
    pub world: usize,
    shared: Arc<Shared>,
    ring_tx: Sender<Tensor>,
    ring_rx: Mutex<Receiver<Tensor>>,
}

impl Comm {
    pub fn new(world: usize) -> (Comm, Vec<CommHandle>) {
        let shared = Arc::new(Shared {
            board: Exchange::new(world),
            board_multi: Exchange::new(world),
            bytes_ag: AtomicU64::new(0),
            bytes_rs: AtomicU64::new(0),
            bytes_p2p: AtomicU64::new(0),
            bytes_a2a: AtomicU64::new(0),
        });
        // ring edges: rank i sends to (i+1) % world
        let mut txs = Vec::with_capacity(world);
        let mut rxs = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        // handle[i] receives on channel i (fed by rank i-1) and sends on
        // channel (i+1) % world.
        let mut handles = Vec::with_capacity(world);
        for rank in 0..world {
            handles.push(CommHandle {
                rank,
                world,
                shared: shared.clone(),
                ring_tx: txs[(rank + 1) % world].clone(),
                ring_rx: Mutex::new(rxs[rank].take().unwrap()),
            });
        }
        (Comm { world, shared }, handles)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// (all-gather, reduce-scatter, p2p, all-to-all) logical bytes so far.
    pub fn traffic(&self) -> (u64, u64, u64, u64) {
        (
            self.shared.bytes_ag.load(Ordering::Relaxed),
            self.shared.bytes_rs.load(Ordering::Relaxed),
            self.shared.bytes_p2p.load(Ordering::Relaxed),
            self.shared.bytes_a2a.load(Ordering::Relaxed),
        )
    }
}

impl CommHandle {
    pub fn barrier(&self) {
        self.shared.board.exchange(self.rank, Tensor::scalar_i32(0));
    }

    /// All-gather: returns every rank's tensor in rank order.  This is the
    /// LASP-2 primitive (paper §2.2.1): one collective on the memory state.
    pub fn all_gather(&self, local: Tensor) -> Vec<Arc<Tensor>> {
        self.shared
            .bytes_ag
            .fetch_add(local.size_bytes() as u64, Ordering::Relaxed);
        self.shared.board.exchange(self.rank, local)
    }

    /// Reduce-scatter (sum): every rank contributes a full-length tensor,
    /// receives the sum of its 1/world shard.  Length must divide evenly.
    pub fn reduce_scatter_sum(&self, local: Tensor) -> Result<Tensor> {
        let n = local.numel();
        anyhow::ensure!(n % self.world == 0,
                        "reduce_scatter: {n} not divisible by world {}", self.world);
        self.shared
            .bytes_rs
            .fetch_add(local.size_bytes() as u64, Ordering::Relaxed);
        let shard = n / self.world;
        let all = self.shared.board.exchange(self.rank, local);
        let lo = self.rank * shard;
        let mut out = vec![0f32; shard];
        for t in &all {
            let v = t.as_f32()?;
            for (o, x) in out.iter_mut().zip(&v[lo..lo + shard]) {
                *o += *x;
            }
        }
        Ok(Tensor::f32(&[shard], out))
    }

    /// All-reduce (sum), decomposed as all-gather + local reduction --
    /// functionally the AG+RS decomposition of paper §A.2.
    pub fn all_reduce_sum(&self, local: Tensor) -> Result<Tensor> {
        let shape = local.shape.clone();
        let all = self.all_gather(local);
        let mut out = vec![0f32; shape.iter().product()];
        for t in &all {
            let v = t.as_f32()?;
            for (o, x) in out.iter_mut().zip(v) {
                *o += *x;
            }
        }
        Ok(Tensor::f32(&shape, out))
    }

    /// Broadcast from `root`.
    pub fn broadcast(&self, root: usize, local: Tensor) -> Arc<Tensor> {
        let all = self.shared.board.exchange(self.rank, local);
        all[root].clone()
    }

    /// Ring point-to-point: send to (rank+1) % world, receive from
    /// (rank-1) % world.  This is LASP-1's communication pattern.
    pub fn ring_shift(&self, send: Tensor) -> Result<Tensor> {
        self.shared
            .bytes_p2p
            .fetch_add(send.size_bytes() as u64, Ordering::Relaxed);
        self.ring_tx.send(send)?;
        Ok(self.ring_rx.lock().unwrap().recv()?)
    }

    /// Asynchronous ring send to (rank+1) % world (used by the LASP-1
    /// sequential prefix chain, where only a neighbour pair synchronizes).
    pub fn ring_send(&self, send: Tensor) -> Result<()> {
        self.shared
            .bytes_p2p
            .fetch_add(send.size_bytes() as u64, Ordering::Relaxed);
        self.ring_tx.send(send)?;
        Ok(())
    }

    /// Blocking ring receive from (rank-1) % world.
    pub fn ring_recv(&self) -> Result<Tensor> {
        Ok(self.ring_rx.lock().unwrap().recv()?)
    }

    /// All-to-all: `parts[d]` goes to rank d; returns what every rank sent
    /// to us (rank order).  The EP token-exchange primitive.
    pub fn all_to_all(&self, parts: Vec<Tensor>) -> Result<Vec<Tensor>> {
        anyhow::ensure!(parts.len() == self.world);
        let bytes: usize = parts.iter().map(|t| t.size_bytes()).sum();
        self.shared
            .bytes_a2a
            .fetch_add(bytes as u64, Ordering::Relaxed);
        let all = self.shared.board_multi.exchange(self.rank, parts);
        Ok(all.iter().map(|v| v[self.rank].clone()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F, R>(world: usize, f: F) -> Vec<R>
    where
        F: Fn(CommHandle) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let (_comm, handles) = Comm::new(world);
        let f = Arc::new(f);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| {
                let f = f.clone();
                thread::spawn(move || f(h))
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let outs = run_world(4, |h| {
            let t = Tensor::f32(&[2], vec![h.rank as f32, 1.0]);
            let all = h.all_gather(t);
            all.iter().map(|t| t.as_f32().unwrap()[0]).collect::<Vec<_>>()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let outs = run_world(3, |h| {
            let t = Tensor::f32(&[3], vec![1.0, h.rank as f32, 2.0]);
            h.all_reduce_sum(t).unwrap().as_f32().unwrap().to_vec()
        });
        for o in outs {
            assert_eq!(o, vec![3.0, 3.0, 6.0]);
        }
    }

    #[test]
    fn reduce_scatter_shards() {
        let outs = run_world(2, |h| {
            let t = Tensor::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]);
            let s = h.reduce_scatter_sum(t).unwrap();
            (h.rank, s.as_f32().unwrap().to_vec())
        });
        for (rank, o) in outs {
            if rank == 0 {
                assert_eq!(o, vec![2.0, 4.0]);
            } else {
                assert_eq!(o, vec![6.0, 8.0]);
            }
        }
    }

    #[test]
    fn ring_shift_rotates() {
        let outs = run_world(4, |h| {
            let t = Tensor::scalar_f32(h.rank as f32);
            let r = h.ring_shift(t).unwrap();
            (h.rank, r.item_f32().unwrap())
        });
        for (rank, v) in outs {
            assert_eq!(v as usize, (rank + 3) % 4);
        }
    }

    #[test]
    fn all_to_all_transposes() {
        let outs = run_world(3, |h| {
            let parts = (0..3)
                .map(|d| Tensor::scalar_f32((h.rank * 10 + d) as f32))
                .collect();
            let got = h.all_to_all(parts).unwrap();
            (h.rank, got.iter().map(|t| t.item_f32().unwrap()).collect::<Vec<_>>())
        });
        for (rank, v) in outs {
            // from rank s we receive s*10 + rank
            let want: Vec<f32> = (0..3).map(|s| (s * 10 + rank) as f32).collect();
            assert_eq!(v, want);
        }
    }

    #[test]
    fn board_reusable_many_rounds() {
        let outs = run_world(4, |h| {
            let mut acc = 0.0;
            for round in 0..50 {
                let t = Tensor::scalar_f32((h.rank + round) as f32);
                acc += h.all_reduce_sum(t).unwrap().item_f32().unwrap();
            }
            acc
        });
        let want: f32 = (0..50).map(|r| (0 + 1 + 2 + 3 + 4 * r) as f32).sum();
        for o in outs {
            assert_eq!(o, want);
        }
    }
}
