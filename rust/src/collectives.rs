//! In-process collective communication library.
//!
//! The paper's cluster is 8 GPUs over NCCL; here a "device" is a worker
//! thread and the transport is shared memory, but the *algorithms* are the
//! same: the LASP AllGather of d×d memory states (paper Alg. 1/2), the
//! TP all-reduce decomposed as all-gather + reduce-scatter (paper §A.2),
//! the EP all-to-all token exchange, and ring point-to-point for LASP-1.
//! Per-handle traffic metering lets benches *measure* the paper's
//! communication-volume claims instead of asserting them.
//!
//! Synchronization: a generation-counted exchange board (deposit slots +
//! condvar).  All ranks must issue collectives in the same program order
//! (standard SPMD contract).
//!
//! Fault tolerance: every collective carries a configurable deadline
//! (`CommCfg::timeout`).  A rank that waits past its deadline **poisons**
//! the board and returns [`CommError::Timeout`]; every peer's pending or
//! subsequent collective then fails fast with [`CommError::PeerFailed`]
//! instead of hanging forever.  A [`FaultPlan`](crate::fault::FaultPlan)
//! threaded into every `CommHandle` lets tests and the `--fault` CLI flag
//! inject rank kills, stragglers, and dropped ring messages
//! deterministically.
//!
//! Chunked all-to-all: [`CommHandle::a2a_post`] / [`CommHandle::a2a_wait`]
//! are the split-phase form of the EP token exchange.  Each micro-shard is
//! posted to a *windowed* exchange board ([`WinExchange`]) under its own
//! sequence number, so several shards can be in flight at once and a
//! receiver can run expert compute on shard *i* while shard *i+1* is still
//! being deposited by peers -- the FSMoE-style comm/compute overlap the
//! MoE engine in `coordinator::moe_ep` schedules.  Deadline and poison
//! semantics apply per shard, exactly as for the blocking collectives.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::fault::{Fault, FaultPlan};
use crate::json::Json;
use crate::tensor::Tensor;
use crate::trace::{TraceHandle, Track};

/// Default collective deadline.  Generous for in-process transports; the
/// CLI / tests lower it via [`CommCfg`].
pub const DEFAULT_COMM_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// Typed communication errors.
// ---------------------------------------------------------------------------

/// Why a collective failed.  `anyhow`-compatible, so coordinator code can
/// `?` it while supervisors downcast to decide on recovery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommError {
    /// This rank waited past its configured deadline.  The board has been
    /// poisoned on this rank's behalf so peers fail fast.
    Timeout { op: &'static str, rank: usize, waited_ms: u64 },
    /// Rank `rank` declared the group failed (it timed out, was killed by
    /// an injected fault, or panicked inside a collective).
    PeerFailed { rank: usize },
    /// This rank already poisoned the group; further ops are rejected.
    Poisoned,
    /// Ring channel disconnected: the neighbour thread exited.
    Disconnected { op: &'static str },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { op, rank, waited_ms } => {
                write!(f, "collective {op} timed out on rank {rank} after {waited_ms} ms")
            }
            CommError::PeerFailed { rank } => {
                write!(f, "collective aborted: rank {rank} failed")
            }
            CommError::Poisoned => write!(f, "communicator is poisoned"),
            CommError::Disconnected { op } => {
                write!(f, "{op}: ring neighbour disconnected")
            }
        }
    }
}

impl std::error::Error for CommError {}

fn poison_err(self_rank: usize, by: usize) -> CommError {
    if by == self_rank {
        CommError::Poisoned
    } else {
        CommError::PeerFailed { rank: by }
    }
}

// ---------------------------------------------------------------------------
// Generic rendezvous board.
// ---------------------------------------------------------------------------

struct BoardState<T> {
    gen: u64,
    filled: usize,
    drained: usize,
    vals: Vec<Option<Arc<T>>>,
    /// Some(rank) once rank has declared the group failed.
    poisoned: Option<usize>,
}

pub struct Exchange<T> {
    state: Mutex<BoardState<T>>,
    cv: Condvar,
    world: usize,
}

impl<T> Exchange<T> {
    pub fn new(world: usize) -> Self {
        Exchange {
            state: Mutex::new(BoardState {
                gen: 0,
                filled: 0,
                drained: 0,
                vals: (0..world).map(|_| None).collect(),
                poisoned: None,
            }),
            cv: Condvar::new(),
            world,
        }
    }

    /// Declare the group failed on behalf of `rank`: wake every waiter and
    /// make all pending and future exchanges fail fast.  First writer wins.
    pub fn poison(&self, rank: usize) {
        let mut st = self.state.lock().unwrap();
        if st.poisoned.is_none() {
            st.poisoned = Some(rank);
        }
        self.cv.notify_all();
    }

    pub fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned.is_some()
    }

    fn wait_or_deadline<'a>(
        &self,
        st: MutexGuard<'a, BoardState<T>>,
        deadline: Instant,
        rank: usize,
        op: &'static str,
        timeout: Duration,
    ) -> Result<MutexGuard<'a, BoardState<T>>, CommError> {
        let now = Instant::now();
        if now >= deadline {
            let mut st = st;
            if st.poisoned.is_none() {
                st.poisoned = Some(rank);
            }
            self.cv.notify_all();
            return Err(CommError::Timeout {
                op,
                rank,
                waited_ms: timeout.as_millis() as u64,
            });
        }
        let (st, _timed_out) = self.cv.wait_timeout(st, deadline - now).unwrap();
        Ok(st)
    }

    /// Deposit this rank's value; block until every rank has deposited or
    /// `timeout` elapses; return all values (rank order).  Reusable across
    /// rounds.  On deadline the caller poisons the board (peers fail fast
    /// with `PeerFailed`); on an already-poisoned board the op is rejected
    /// immediately.
    pub fn exchange_deadline(
        &self,
        rank: usize,
        val: T,
        timeout: Duration,
        op: &'static str,
    ) -> Result<Vec<Arc<T>>, CommError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        // Wait for our slot from the previous round to be fully drained.
        loop {
            if let Some(by) = st.poisoned {
                return Err(poison_err(rank, by));
            }
            if st.vals[rank].is_none() {
                break;
            }
            st = self.wait_or_deadline(st, deadline, rank, op, timeout)?;
        }
        st.vals[rank] = Some(Arc::new(val));
        st.filled += 1;
        let my_gen = st.gen;
        if st.filled == self.world {
            self.cv.notify_all();
        }
        // Wait until every rank of this generation has deposited.
        while st.gen == my_gen && st.filled < self.world {
            if let Some(by) = st.poisoned {
                return Err(poison_err(rank, by));
            }
            st = self.wait_or_deadline(st, deadline, rank, op, timeout)?;
        }
        if let Some(by) = st.poisoned {
            return Err(poison_err(rank, by));
        }
        let out: Vec<Arc<T>> = st.vals.iter().map(|v| v.clone().unwrap()).collect();
        st.drained += 1;
        if st.drained == self.world {
            for v in st.vals.iter_mut() {
                *v = None;
            }
            st.filled = 0;
            st.drained = 0;
            st.gen += 1;
            self.cv.notify_all();
        }
        Ok(out)
    }

    /// Back-compat convenience with the default deadline.
    pub fn exchange(&self, rank: usize, val: T) -> Result<Vec<Arc<T>>, CommError> {
        self.exchange_deadline(rank, val, DEFAULT_COMM_TIMEOUT, "exchange")
    }
}

// ---------------------------------------------------------------------------
// Windowed rendezvous board: several generations in flight at once.
// ---------------------------------------------------------------------------

/// How many rounds may be in flight before we assume the SPMD contract was
/// violated (ranks posting wildly different sequences).  The MoE overlap
/// scheduler keeps at most 3 shards outstanding; 64 is a generous cap.
const WIN_MAX_IN_FLIGHT: usize = 64;

struct WinSlot<T> {
    vals: Vec<Option<Arc<T>>>,
    filled: usize,
    drained: usize,
}

struct WinState<T> {
    slots: BTreeMap<u64, WinSlot<T>>,
    poisoned: Option<usize>,
}

/// Split-phase exchange board keyed by an explicit round number: `post` is
/// non-blocking, `wait` blocks until every rank deposited that round.
/// Unlike [`Exchange`], multiple rounds may be open simultaneously, which
/// is what lets chunked all-to-all shards pipeline.  All ranks must post
/// and wait rounds in the same order (SPMD contract); a deadline in `wait`
/// poisons the whole board.
pub struct WinExchange<T> {
    state: Mutex<WinState<T>>,
    cv: Condvar,
    world: usize,
}

impl<T> WinExchange<T> {
    pub fn new(world: usize) -> Self {
        WinExchange {
            state: Mutex::new(WinState { slots: BTreeMap::new(), poisoned: None }),
            cv: Condvar::new(),
            world,
        }
    }

    /// Declare the group failed on behalf of `rank` (first writer wins).
    pub fn poison(&self, rank: usize) {
        let mut st = self.state.lock().unwrap();
        if st.poisoned.is_none() {
            st.poisoned = Some(rank);
        }
        self.cv.notify_all();
    }

    pub fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned.is_some()
    }

    /// Deposit `rank`'s contribution to round `seq` without blocking.
    pub fn post(&self, rank: usize, seq: u64, val: T) -> Result<(), CommError> {
        let mut st = self.state.lock().unwrap();
        if let Some(by) = st.poisoned {
            return Err(poison_err(rank, by));
        }
        if st.slots.len() >= WIN_MAX_IN_FLIGHT && !st.slots.contains_key(&seq) {
            panic!(
                "windowed exchange overflow: {} rounds in flight posting seq {seq} \
                 (ranks issuing collectives out of SPMD order?)",
                st.slots.len()
            );
        }
        let world = self.world;
        let slot = st.slots.entry(seq).or_insert_with(|| WinSlot {
            vals: (0..world).map(|_| None).collect(),
            filled: 0,
            drained: 0,
        });
        assert!(
            slot.vals[rank].is_none(),
            "rank {rank} double-posted windowed exchange seq {seq}"
        );
        slot.vals[rank] = Some(Arc::new(val));
        slot.filled += 1;
        if slot.filled == world {
            self.cv.notify_all();
        }
        Ok(())
    }

    /// Block until every rank has posted round `seq`; returns the round's
    /// values in rank order.  On deadline the board is poisoned so peers
    /// fail fast, mirroring [`Exchange::exchange_deadline`].
    pub fn wait(
        &self,
        rank: usize,
        seq: u64,
        timeout: Duration,
        op: &'static str,
    ) -> Result<Vec<Arc<T>>, CommError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(by) = st.poisoned {
                return Err(poison_err(rank, by));
            }
            if st.slots.get(&seq).is_some_and(|s| s.filled == self.world) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                if st.poisoned.is_none() {
                    st.poisoned = Some(rank);
                }
                self.cv.notify_all();
                return Err(CommError::Timeout {
                    op,
                    rank,
                    waited_ms: timeout.as_millis() as u64,
                });
            }
            let (g, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
        let slot = st.slots.get_mut(&seq).unwrap();
        let out: Vec<Arc<T>> = slot.vals.iter().map(|v| v.clone().unwrap()).collect();
        slot.drained += 1;
        if slot.drained == self.world {
            st.slots.remove(&seq);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Process group.
// ---------------------------------------------------------------------------

/// Communicator configuration: collective deadline + fault-injection plan
/// + optional trace sink (per-op spans land on the `("comm", rank)` track).
#[derive(Clone)]
pub struct CommCfg {
    pub timeout: Duration,
    pub faults: Arc<FaultPlan>,
    pub tracer: TraceHandle,
}

impl Default for CommCfg {
    fn default() -> Self {
        CommCfg {
            timeout: DEFAULT_COMM_TIMEOUT,
            faults: Arc::new(FaultPlan::none()),
            tracer: TraceHandle::none(),
        }
    }
}

/// Per-collective-kind traffic attribution: logical bytes and op launches
/// for each primitive, so benches can *verify* the paper's EP
/// communication-volume claim (tokens × d × 4 B per all-to-all direction)
/// instead of asserting it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommTraffic {
    pub all_gather_bytes: u64,
    pub all_gather_ops: u64,
    pub reduce_scatter_bytes: u64,
    pub reduce_scatter_ops: u64,
    pub ring_bytes: u64,
    pub ring_ops: u64,
    pub all_to_all_bytes: u64,
    pub all_to_all_ops: u64,
}

impl CommTraffic {
    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes
            + self.reduce_scatter_bytes
            + self.ring_bytes
            + self.all_to_all_bytes
    }

    /// Accumulate another group's counters (the resilient trainer builds a
    /// fresh communicator per attempt and sums their traffic).
    pub fn merge(&mut self, o: CommTraffic) {
        self.all_gather_bytes += o.all_gather_bytes;
        self.all_gather_ops += o.all_gather_ops;
        self.reduce_scatter_bytes += o.reduce_scatter_bytes;
        self.reduce_scatter_ops += o.reduce_scatter_ops;
        self.ring_bytes += o.ring_bytes;
        self.ring_ops += o.ring_ops;
        self.all_to_all_bytes += o.all_to_all_bytes;
        self.all_to_all_ops += o.all_to_all_ops;
    }
}

/// Counters for observed / injected failures (group-wide totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommFaultStats {
    pub timeouts: u64,
    pub peer_failures: u64,
    pub injected_kills: u64,
    pub injected_delays: u64,
    pub dropped_ring: u64,
}

impl CommFaultStats {
    /// Accumulate another group's counters (the resilient trainer builds a
    /// fresh communicator per attempt and sums their stats).
    pub fn merge(&mut self, o: CommFaultStats) {
        self.timeouts += o.timeouts;
        self.peer_failures += o.peer_failures;
        self.injected_kills += o.injected_kills;
        self.injected_delays += o.injected_delays;
        self.dropped_ring += o.dropped_ring;
    }
}

struct Shared {
    board: Exchange<Tensor>,
    board_multi: Exchange<Vec<Tensor>>,
    /// windowed board for the chunked (split-phase) all-to-all shards
    win: WinExchange<Vec<Tensor>>,
    /// logical bytes moved across the group (sum over ranks of bytes each
    /// rank contributed to the wire), per op class
    bytes_ag: AtomicU64,
    bytes_rs: AtomicU64,
    bytes_p2p: AtomicU64,
    bytes_a2a: AtomicU64,
    // per-kind op launch counts (group-wide)
    ops_ag: AtomicU64,
    ops_rs: AtomicU64,
    ops_p2p: AtomicU64,
    ops_a2a: AtomicU64,
    // fault observability
    timeouts: AtomicU64,
    peer_failures: AtomicU64,
    injected_kills: AtomicU64,
    injected_delays: AtomicU64,
    dropped_ring: AtomicU64,
}

/// A communicator over `world` ranks.  Clone-free: call `handles()` once
/// and move each `CommHandle` into its worker thread.
pub struct Comm {
    world: usize,
    shared: Arc<Shared>,
}

pub struct CommHandle {
    pub rank: usize,
    pub world: usize,
    shared: Arc<Shared>,
    ring_tx: Sender<Tensor>,
    ring_rx: Mutex<Receiver<Tensor>>,
    timeout: Duration,
    faults: Arc<FaultPlan>,
    /// current training step, set by the worker loop so faults addressed
    /// by (rank, step) can match
    step: AtomicU64,
    /// next chunked-a2a shard sequence number (per-rank; the SPMD program
    /// order guarantees all ranks assign identical sequences)
    a2a_seq: AtomicU64,
    trace: TraceHandle,
}

/// Receipt for a posted all-to-all shard.  Redeem with
/// [`CommHandle::a2a_wait`]; dropping it without waiting stalls peers
/// until their deadline.
#[must_use = "a posted all-to-all shard must be waited on"]
#[derive(Debug)]
pub struct A2aTicket {
    seq: u64,
}

impl Comm {
    pub fn new(world: usize) -> (Comm, Vec<CommHandle>) {
        Comm::new_with(world, CommCfg::default())
    }

    pub fn new_with(world: usize, cfg: CommCfg) -> (Comm, Vec<CommHandle>) {
        let shared = Arc::new(Shared {
            board: Exchange::new(world),
            board_multi: Exchange::new(world),
            win: WinExchange::new(world),
            bytes_ag: AtomicU64::new(0),
            bytes_rs: AtomicU64::new(0),
            bytes_p2p: AtomicU64::new(0),
            bytes_a2a: AtomicU64::new(0),
            ops_ag: AtomicU64::new(0),
            ops_rs: AtomicU64::new(0),
            ops_p2p: AtomicU64::new(0),
            ops_a2a: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            peer_failures: AtomicU64::new(0),
            injected_kills: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            dropped_ring: AtomicU64::new(0),
        });
        // ring edges: rank i sends to (i+1) % world
        let mut txs = Vec::with_capacity(world);
        let mut rxs = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        // handle[i] receives on channel i (fed by rank i-1) and sends on
        // channel (i+1) % world.
        let mut handles = Vec::with_capacity(world);
        for rank in 0..world {
            handles.push(CommHandle {
                rank,
                world,
                shared: shared.clone(),
                ring_tx: txs[(rank + 1) % world].clone(),
                ring_rx: Mutex::new(rxs[rank].take().unwrap()),
                timeout: cfg.timeout,
                faults: cfg.faults.clone(),
                step: AtomicU64::new(0),
                a2a_seq: AtomicU64::new(0),
                trace: cfg.tracer.clone(),
            });
        }
        (Comm { world, shared }, handles)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// (all-gather, reduce-scatter, p2p, all-to-all) logical bytes so far.
    pub fn traffic(&self) -> (u64, u64, u64, u64) {
        (
            self.shared.bytes_ag.load(Ordering::Relaxed),
            self.shared.bytes_rs.load(Ordering::Relaxed),
            self.shared.bytes_p2p.load(Ordering::Relaxed),
            self.shared.bytes_a2a.load(Ordering::Relaxed),
        )
    }

    /// Traffic attributed per collective kind (bytes + op launches).
    pub fn traffic_by_kind(&self) -> CommTraffic {
        self.shared.traffic_by_kind()
    }

    /// Failure counters accumulated by the group's handles.
    pub fn fault_stats(&self) -> CommFaultStats {
        CommFaultStats {
            timeouts: self.shared.timeouts.load(Ordering::Relaxed),
            peer_failures: self.shared.peer_failures.load(Ordering::Relaxed),
            injected_kills: self.shared.injected_kills.load(Ordering::Relaxed),
            injected_delays: self.shared.injected_delays.load(Ordering::Relaxed),
            dropped_ring: self.shared.dropped_ring.load(Ordering::Relaxed),
        }
    }

    /// True once any rank has poisoned any exchange board.
    pub fn is_poisoned(&self) -> bool {
        self.shared.board.is_poisoned()
            || self.shared.board_multi.is_poisoned()
            || self.shared.win.is_poisoned()
    }
}

impl Shared {
    fn traffic_by_kind(&self) -> CommTraffic {
        CommTraffic {
            all_gather_bytes: self.bytes_ag.load(Ordering::Relaxed),
            all_gather_ops: self.ops_ag.load(Ordering::Relaxed),
            reduce_scatter_bytes: self.bytes_rs.load(Ordering::Relaxed),
            reduce_scatter_ops: self.ops_rs.load(Ordering::Relaxed),
            ring_bytes: self.bytes_p2p.load(Ordering::Relaxed),
            ring_ops: self.ops_p2p.load(Ordering::Relaxed),
            all_to_all_bytes: self.bytes_a2a.load(Ordering::Relaxed),
            all_to_all_ops: self.ops_a2a.load(Ordering::Relaxed),
        }
    }
}

impl CommHandle {
    /// Record the current training step so (rank, step)-addressed faults
    /// can match.  Called once per step by worker loops.
    pub fn set_step(&self, step: usize) {
        self.step.store(step as u64, Ordering::Relaxed);
    }

    pub fn cur_step(&self) -> usize {
        self.step.load(Ordering::Relaxed) as usize
    }

    /// The trace sink this communicator emits into (no-op unless the
    /// group was built with `CommCfg::tracer`).  EP and worker loops use
    /// it to put their own spans on the same timeline.
    pub fn tracer(&self) -> &TraceHandle {
        &self.trace
    }

    fn track(&self) -> Track {
        Track::new("comm", self.rank as u64)
    }

    /// Timeout/poison annotation: one `comm.error` instant per failed op.
    fn trace_err(&self, op: &'static str, e: &CommError) {
        if self.trace.on() {
            self.trace.instant(
                self.track(),
                "comm",
                "comm.error",
                self.cur_step() as u64,
                vec![
                    ("op".to_string(), Json::from(op)),
                    ("err".to_string(), Json::from(format!("{e}"))),
                ],
            );
        }
    }

    /// Consult the fault plan on entry to a collective.  Delays sleep here;
    /// kills poison both boards (so peers fail fast with `PeerFailed`)
    /// and then panic, modelling a hard rank death.
    fn preflight(&self, op: &'static str) {
        match self.faults.take_collective(self.rank, self.cur_step()) {
            Some(Fault::DelayCollective { ms, .. }) => {
                self.shared.injected_delays.fetch_add(1, Ordering::Relaxed);
                if self.trace.on() {
                    self.trace.instant(
                        self.track(),
                        "fault",
                        "fault.delay",
                        self.cur_step() as u64,
                        vec![
                            ("op".to_string(), Json::from(op)),
                            ("ms".to_string(), Json::from(ms)),
                        ],
                    );
                }
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some(Fault::KillRank { rank, step }) => {
                self.shared.injected_kills.fetch_add(1, Ordering::Relaxed);
                if self.trace.on() {
                    self.trace.instant(
                        self.track(),
                        "fault",
                        "fault.kill",
                        step as u64,
                        vec![
                            ("op".to_string(), Json::from(op)),
                            ("rank".to_string(), Json::from(rank)),
                        ],
                    );
                }
                self.shared.board.poison(rank);
                self.shared.board_multi.poison(rank);
                self.shared.win.poison(rank);
                panic!("injected fault: kill rank {rank} at step {step} (in {op})");
            }
            _ => {}
        }
    }

    fn record_err(&self, e: &CommError) {
        match e {
            CommError::Timeout { .. } => {
                self.shared.timeouts.fetch_add(1, Ordering::Relaxed);
            }
            CommError::PeerFailed { .. } => {
                self.shared.peer_failures.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    fn board_exchange(
        &self,
        val: Tensor,
        op: &'static str,
    ) -> Result<Vec<Arc<Tensor>>, CommError> {
        self.preflight(op);
        let t0 = Instant::now();
        let res = self
            .shared
            .board
            .exchange_deadline(self.rank, val, self.timeout, op)
            .map_err(|e| {
                self.record_err(&e);
                self.trace_err(op, &e);
                e
            });
        if res.is_ok() && self.trace.on() {
            self.trace.span_timed(
                self.track(),
                "comm",
                &format!("comm.{op}"),
                self.cur_step() as u64,
                0,
                t0.elapsed(),
                Vec::new(),
            );
        }
        res
    }

    pub fn barrier(&self) -> Result<(), CommError> {
        self.board_exchange(Tensor::scalar_i32(0), "barrier")?;
        Ok(())
    }

    /// All-gather: returns every rank's tensor in rank order.  This is the
    /// LASP-2 primitive (paper §2.2.1): one collective on the memory state.
    pub fn all_gather(&self, local: Tensor) -> Result<Vec<Arc<Tensor>>, CommError> {
        self.shared
            .bytes_ag
            .fetch_add(local.size_bytes() as u64, Ordering::Relaxed);
        self.shared.ops_ag.fetch_add(1, Ordering::Relaxed);
        self.board_exchange(local, "all_gather")
    }

    /// Reduce-scatter (sum): every rank contributes a full-length tensor,
    /// receives the sum of its 1/world shard.  Length must divide evenly.
    pub fn reduce_scatter_sum(&self, local: Tensor) -> Result<Tensor> {
        let n = local.numel();
        anyhow::ensure!(n % self.world == 0,
                        "reduce_scatter: {n} not divisible by world {}", self.world);
        self.shared
            .bytes_rs
            .fetch_add(local.size_bytes() as u64, Ordering::Relaxed);
        self.shared.ops_rs.fetch_add(1, Ordering::Relaxed);
        let shard = n / self.world;
        let all = self.board_exchange(local, "reduce_scatter")?;
        let lo = self.rank * shard;
        let mut out = vec![0f32; shard];
        for t in &all {
            let v = t.as_f32()?;
            for (o, x) in out.iter_mut().zip(&v[lo..lo + shard]) {
                *o += *x;
            }
        }
        Ok(Tensor::f32(&[shard], out))
    }

    /// All-reduce (sum), decomposed as all-gather + local reduction --
    /// functionally the AG+RS decomposition of paper §A.2.
    pub fn all_reduce_sum(&self, local: Tensor) -> Result<Tensor> {
        let shape = local.shape.clone();
        let all = self.all_gather(local)?;
        let mut out = vec![0f32; shape.iter().product()];
        for t in &all {
            let v = t.as_f32()?;
            for (o, x) in out.iter_mut().zip(v) {
                *o += *x;
            }
        }
        Ok(Tensor::f32(&shape, out))
    }

    /// Broadcast from `root`.
    pub fn broadcast(&self, root: usize, local: Tensor) -> Result<Arc<Tensor>, CommError> {
        let all = self.board_exchange(local, "broadcast")?;
        Ok(all[root].clone())
    }

    /// Ring point-to-point: send to (rank+1) % world, receive from
    /// (rank-1) % world.  This is LASP-1's communication pattern.
    pub fn ring_shift(&self, send: Tensor) -> Result<Tensor> {
        self.ring_send(send)?;
        self.ring_recv()
    }

    /// Asynchronous ring send to (rank+1) % world (used by the LASP-1
    /// sequential prefix chain, where only a neighbour pair synchronizes).
    /// An injected `DropRing` fault discards the message (the receiver's
    /// deadline then fires).
    pub fn ring_send(&self, send: Tensor) -> Result<()> {
        self.preflight("ring_send");
        if self.faults.take_drop_ring(self.rank, self.cur_step()).is_some() {
            self.shared.dropped_ring.fetch_add(1, Ordering::Relaxed);
            if self.trace.on() {
                self.trace.instant(
                    self.track(),
                    "fault",
                    "fault.drop_ring",
                    self.cur_step() as u64,
                    Vec::new(),
                );
            }
            return Ok(());
        }
        self.shared
            .bytes_p2p
            .fetch_add(send.size_bytes() as u64, Ordering::Relaxed);
        self.shared.ops_p2p.fetch_add(1, Ordering::Relaxed);
        self.ring_tx
            .send(send)
            .map_err(|_| CommError::Disconnected { op: "ring_send" })?;
        Ok(())
    }

    /// Ring receive from (rank-1) % world with the configured deadline.
    /// A deadline poisons the boards (the ring and board collectives share
    /// fate: a dead neighbour breaks both).
    pub fn ring_recv(&self) -> Result<Tensor> {
        self.preflight("ring_recv");
        match self.ring_rx.lock().unwrap().recv_timeout(self.timeout) {
            Ok(t) => Ok(t),
            Err(RecvTimeoutError::Timeout) => {
                let e = CommError::Timeout {
                    op: "ring_recv",
                    rank: self.rank,
                    waited_ms: self.timeout.as_millis() as u64,
                };
                self.record_err(&e);
                self.trace_err("ring_recv", &e);
                self.shared.board.poison(self.rank);
                self.shared.board_multi.poison(self.rank);
                self.shared.win.poison(self.rank);
                Err(e.into())
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(CommError::Disconnected { op: "ring_recv" }.into())
            }
        }
    }

    /// All-to-all: `parts[d]` goes to rank d; returns what every rank sent
    /// to us (rank order).  The EP token-exchange primitive.
    pub fn all_to_all(&self, parts: Vec<Tensor>) -> Result<Vec<Tensor>> {
        anyhow::ensure!(parts.len() == self.world);
        let bytes: usize = parts.iter().map(|t| t.size_bytes()).sum();
        self.shared
            .bytes_a2a
            .fetch_add(bytes as u64, Ordering::Relaxed);
        self.shared.ops_a2a.fetch_add(1, Ordering::Relaxed);
        self.preflight("all_to_all");
        let t0 = Instant::now();
        let all = self
            .shared
            .board_multi
            .exchange_deadline(self.rank, parts, self.timeout, "all_to_all")
            .map_err(|e| {
                self.record_err(&e);
                self.trace_err("all_to_all", &e);
                e
            })?;
        if self.trace.on() {
            self.trace.span_timed(
                self.track(),
                "comm",
                "comm.all_to_all",
                self.cur_step() as u64,
                0,
                t0.elapsed(),
                vec![("bytes".to_string(), Json::from(bytes))],
            );
        }
        Ok(all.iter().map(|v| v[self.rank].clone()).collect())
    }

    /// Post one micro-shard of a chunked all-to-all without blocking:
    /// `parts[d]` goes to rank d.  Returns a ticket to redeem with
    /// [`a2a_wait`](Self::a2a_wait).  All ranks must post and wait shards
    /// in the same program order (SPMD contract); several shards may be in
    /// flight at once, which is what lets the MoE engine overlap expert
    /// compute on shard *i* with the exchange of shard *i+1*.
    pub fn a2a_post(&self, parts: Vec<Tensor>) -> Result<A2aTicket> {
        anyhow::ensure!(
            parts.len() == self.world,
            "a2a_post: {} parts for world {}",
            parts.len(),
            self.world
        );
        let bytes: usize = parts.iter().map(|t| t.size_bytes()).sum();
        self.shared
            .bytes_a2a
            .fetch_add(bytes as u64, Ordering::Relaxed);
        self.shared.ops_a2a.fetch_add(1, Ordering::Relaxed);
        self.preflight("a2a_post");
        let seq = self.a2a_seq.fetch_add(1, Ordering::Relaxed);
        self.shared.win.post(self.rank, seq, parts).map_err(|e| {
            self.record_err(&e);
            self.trace_err("a2a_post", &e);
            e
        })?;
        if self.trace.on() {
            self.trace.instant(
                self.track(),
                "comm",
                "a2a.post",
                self.cur_step() as u64,
                vec![
                    ("seq".to_string(), Json::from(seq)),
                    ("bytes".to_string(), Json::from(bytes)),
                ],
            );
        }
        Ok(A2aTicket { seq })
    }

    /// Complete a posted shard: blocks (with the configured deadline) until
    /// every rank has posted the same shard, then returns what each rank
    /// sent to us, in source-rank order.  A deadline poisons all boards so
    /// peers blocked anywhere fail fast.
    pub fn a2a_wait(&self, ticket: A2aTicket) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let res = self
            .shared
            .win
            .wait(self.rank, ticket.seq, self.timeout, "a2a_wait");
        match res {
            Ok(all) => {
                if self.trace.on() {
                    self.trace.span_timed(
                        self.track(),
                        "comm",
                        "a2a.wait",
                        self.cur_step() as u64,
                        0,
                        t0.elapsed(),
                        vec![("seq".to_string(), Json::from(ticket.seq))],
                    );
                }
                Ok(all.iter().map(|v| v[self.rank].clone()).collect())
            }
            Err(e) => {
                self.record_err(&e);
                self.trace_err("a2a_wait", &e);
                if matches!(e, CommError::Timeout { .. }) {
                    self.shared.board.poison(self.rank);
                    self.shared.board_multi.poison(self.rank);
                }
                Err(e.into())
            }
        }
    }

    /// Per-kind traffic snapshot (group-wide), for rank-side reporting.
    pub fn traffic_by_kind(&self) -> CommTraffic {
        self.shared.traffic_by_kind()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F, R>(world: usize, f: F) -> Vec<R>
    where
        F: Fn(CommHandle) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let (_comm, handles) = Comm::new(world);
        let f = Arc::new(f);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| {
                let f = f.clone();
                thread::spawn(move || f(h))
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let outs = run_world(4, |h| {
            let t = Tensor::f32(&[2], vec![h.rank as f32, 1.0]);
            let all = h.all_gather(t).unwrap();
            all.iter().map(|t| t.as_f32().unwrap()[0]).collect::<Vec<_>>()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let outs = run_world(3, |h| {
            let t = Tensor::f32(&[3], vec![1.0, h.rank as f32, 2.0]);
            h.all_reduce_sum(t).unwrap().as_f32().unwrap().to_vec()
        });
        for o in outs {
            assert_eq!(o, vec![3.0, 3.0, 6.0]);
        }
    }

    #[test]
    fn reduce_scatter_shards() {
        let outs = run_world(2, |h| {
            let t = Tensor::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]);
            let s = h.reduce_scatter_sum(t).unwrap();
            (h.rank, s.as_f32().unwrap().to_vec())
        });
        for (rank, o) in outs {
            if rank == 0 {
                assert_eq!(o, vec![2.0, 4.0]);
            } else {
                assert_eq!(o, vec![6.0, 8.0]);
            }
        }
    }

    #[test]
    fn ring_shift_rotates() {
        let outs = run_world(4, |h| {
            let t = Tensor::scalar_f32(h.rank as f32);
            let r = h.ring_shift(t).unwrap();
            (h.rank, r.item_f32().unwrap())
        });
        for (rank, v) in outs {
            assert_eq!(v as usize, (rank + 3) % 4);
        }
    }

    #[test]
    fn all_to_all_transposes() {
        let outs = run_world(3, |h| {
            let parts = (0..3)
                .map(|d| Tensor::scalar_f32((h.rank * 10 + d) as f32))
                .collect();
            let got = h.all_to_all(parts).unwrap();
            (h.rank, got.iter().map(|t| t.item_f32().unwrap()).collect::<Vec<_>>())
        });
        for (rank, v) in outs {
            // from rank s we receive s*10 + rank
            let want: Vec<f32> = (0..3).map(|s| (s * 10 + rank) as f32).collect();
            assert_eq!(v, want);
        }
    }

    #[test]
    fn board_reusable_many_rounds() {
        let outs = run_world(4, |h| {
            let mut acc = 0.0;
            for round in 0..50 {
                let t = Tensor::scalar_f32((h.rank + round) as f32);
                acc += h.all_reduce_sum(t).unwrap().item_f32().unwrap();
            }
            acc
        });
        let want: f32 = (0..50).map(|r| (6 + 4 * r) as f32).sum();
        for o in outs {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn timeout_fires_when_peer_never_arrives() {
        let cfg = CommCfg { timeout: Duration::from_millis(50), ..Default::default() };
        let (comm, mut handles) = Comm::new_with(2, cfg);
        let h0 = handles.remove(0);
        // rank 1 never calls the collective
        let t0 = Instant::now();
        let err = h0.all_gather(Tensor::scalar_f32(0.0)).unwrap_err();
        assert!(matches!(err, CommError::Timeout { rank: 0, .. }), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "must not block forever");
        assert!(comm.is_poisoned());
        assert_eq!(comm.fault_stats().timeouts, 1);
    }

    #[test]
    fn poisoned_board_rejects_subsequent_ops() {
        let cfg = CommCfg { timeout: Duration::from_millis(20), ..Default::default() };
        let (_comm, mut handles) = Comm::new_with(2, cfg);
        let h1 = handles.remove(1);
        let h0 = handles.remove(0);
        let _ = h0.all_gather(Tensor::scalar_f32(0.0)).unwrap_err(); // poisons
        // the late peer is told rank 0 failed, immediately
        let t0 = Instant::now();
        let err = h1.all_gather(Tensor::scalar_f32(1.0)).unwrap_err();
        assert_eq!(err, CommError::PeerFailed { rank: 0 });
        assert!(t0.elapsed() < Duration::from_millis(20));
        // and the poisoner itself is told the group is dead
        let err = h0.barrier().unwrap_err();
        assert_eq!(err, CommError::Poisoned);
    }

    #[test]
    fn injected_delay_slows_but_completes() {
        let faults = Arc::new(FaultPlan::parse("delay:rank=0,step=0,ms=30").unwrap());
        let cfg = CommCfg { timeout: Duration::from_secs(5), faults, ..Default::default() };
        let (comm, handles) = Comm::new_with(2, cfg);
        let t0 = Instant::now();
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| thread::spawn(move || h.all_reduce_sum(Tensor::scalar_f32(1.0)).unwrap()))
            .collect();
        for j in joins {
            assert_eq!(j.join().unwrap().item_f32().unwrap(), 2.0);
        }
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(comm.fault_stats().injected_delays, 1);
    }

    #[test]
    fn injected_kill_panics_rank_and_fails_peers_fast() {
        let faults = Arc::new(FaultPlan::parse("kill:rank=1,step=0").unwrap());
        let cfg = CommCfg { timeout: Duration::from_secs(30), faults, ..Default::default() };
        let (comm, handles) = Comm::new_with(2, cfg);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| thread::spawn(move || h.all_gather(Tensor::scalar_f32(0.0)).map(|_| ())))
            .collect();
        let t0 = Instant::now();
        let results: Vec<_> = joins.into_iter().map(|j| j.join()).collect();
        // rank 0: clean CommError; rank 1: panicked
        assert_eq!(
            results[0].as_ref().unwrap().unwrap_err(),
            CommError::PeerFailed { rank: 1 }
        );
        assert!(results[1].is_err(), "rank 1 must have panicked");
        // peers failed fast -- nowhere near the 30 s deadline
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(comm.fault_stats().injected_kills, 1);
    }

    #[test]
    fn chunked_a2a_transposes_like_blocking() {
        let outs = run_world(3, |h| {
            // two shards in flight at once; values encode (rank, dst, shard)
            let mk = |shard: usize| {
                (0..3)
                    .map(|d| Tensor::scalar_f32((h.rank * 100 + d * 10 + shard) as f32))
                    .collect::<Vec<_>>()
            };
            let t0 = h.a2a_post(mk(0)).unwrap();
            let t1 = h.a2a_post(mk(1)).unwrap();
            let r0 = h.a2a_wait(t0).unwrap();
            let r1 = h.a2a_wait(t1).unwrap();
            let vals = |r: Vec<Tensor>| {
                r.iter().map(|t| t.item_f32().unwrap()).collect::<Vec<_>>()
            };
            (h.rank, vals(r0), vals(r1))
        });
        for (rank, r0, r1) in outs {
            let want = |shard: usize| {
                (0..3)
                    .map(|s| (s * 100 + rank * 10 + shard) as f32)
                    .collect::<Vec<f32>>()
            };
            assert_eq!(r0, want(0));
            assert_eq!(r1, want(1));
        }
    }

    #[test]
    fn chunked_a2a_many_rounds_reuses_board() {
        let outs = run_world(2, |h| {
            let mut acc = 0.0;
            for round in 0..40 {
                let parts = (0..2)
                    .map(|d| Tensor::scalar_f32((h.rank + d + round) as f32))
                    .collect();
                let t = h.a2a_post(parts).unwrap();
                for r in h.a2a_wait(t).unwrap() {
                    acc += r.item_f32().unwrap();
                }
            }
            acc
        });
        // each rank receives (s + rank + round) from s in {0,1}
        for (rank, acc) in outs.into_iter().enumerate() {
            let want: f32 = (0..40)
                .map(|r| (rank + r) as f32 + (1 + rank + r) as f32)
                .sum();
            assert_eq!(acc, want);
        }
    }

    #[test]
    fn chunked_a2a_wait_times_out_and_poisons() {
        let cfg = CommCfg { timeout: Duration::from_millis(50), ..Default::default() };
        let (comm, mut handles) = Comm::new_with(2, cfg);
        let h0 = handles.remove(0);
        // rank 1 never posts its shard
        let t = h0
            .a2a_post(vec![Tensor::scalar_f32(0.0), Tensor::scalar_f32(1.0)])
            .unwrap();
        let err = h0.a2a_wait(t).unwrap_err();
        let ce = err.downcast_ref::<CommError>().unwrap();
        assert!(matches!(ce, CommError::Timeout { op: "a2a_wait", rank: 0, .. }), "{ce}");
        assert!(comm.is_poisoned());
        assert_eq!(comm.fault_stats().timeouts, 1);
    }

    #[test]
    fn traffic_by_kind_attributes_per_collective() {
        let (comm, handles) = Comm::new(2);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| {
                thread::spawn(move || {
                    h.all_gather(Tensor::f32(&[4], vec![0.0; 4])).unwrap();
                    h.reduce_scatter_sum(Tensor::f32(&[2], vec![0.0; 2])).unwrap();
                    let t = h
                        .a2a_post(vec![
                            Tensor::f32(&[3], vec![0.0; 3]),
                            Tensor::f32(&[3], vec![0.0; 3]),
                        ])
                        .unwrap();
                    h.a2a_wait(t).unwrap();
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let t = comm.traffic_by_kind();
        assert_eq!(t.all_gather_ops, 2);
        assert_eq!(t.all_gather_bytes, 2 * 4 * 4);
        assert_eq!(t.reduce_scatter_ops, 2);
        assert_eq!(t.reduce_scatter_bytes, 2 * 2 * 4);
        assert_eq!(t.all_to_all_ops, 2);
        assert_eq!(t.all_to_all_bytes, 2 * 2 * 3 * 4);
        assert_eq!(t.ring_ops, 0);
        assert_eq!(t.total_bytes(), t.all_gather_bytes + t.reduce_scatter_bytes + t.all_to_all_bytes);
        // back-compat 4-tuple view still agrees
        let (ag, rs, p2p, a2a) = comm.traffic();
        assert_eq!((ag, rs, p2p, a2a), (t.all_gather_bytes, t.reduce_scatter_bytes, t.ring_bytes, t.all_to_all_bytes));
    }

    #[test]
    fn dropped_ring_message_times_out_receiver() {
        let faults = Arc::new(FaultPlan::parse("drop_ring:rank=0,step=0").unwrap());
        let cfg = CommCfg { timeout: Duration::from_millis(50), faults, ..Default::default() };
        let (comm, mut handles) = Comm::new_with(2, cfg);
        let h1 = handles.remove(1);
        let h0 = handles.remove(0);
        h0.ring_send(Tensor::scalar_f32(7.0)).unwrap(); // dropped
        let err = h1.ring_recv().unwrap_err();
        let ce = err.downcast_ref::<CommError>().unwrap();
        assert!(matches!(ce, CommError::Timeout { op: "ring_recv", rank: 1, .. }), "{ce}");
        assert_eq!(comm.fault_stats().dropped_ring, 1);
        assert!(comm.is_poisoned());
    }
}
