//! Analytical accelerator-memory model.
//!
//! The paper's Table 3/4 memory columns are A100-80G numbers; this testbed
//! has no GPU, so memory is *modeled*: params + grads + optimizer states +
//! activations + (at decode) KV cache vs LSM state, under the active
//! parallelism config.  The model counts exactly the terms that dominate
//! the paper's numbers, so the *shape* (quadratic/linear/flat growth in
//! sequence length; EP/TP/PP sharding ratios) reproduces even though the
//! absolute scale is whatever model size we instantiate.
//!
//! All quantities in bytes, f32 elements (4 bytes) unless noted.

use crate::runtime::ModelConfig;

pub const ELT: usize = 4; // f32

#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelCfg {
    pub dp: usize,
    pub sp: usize,
    pub pp: usize,
    pub tp: usize,
    pub ep: usize,
    /// ZeRO-1 distributed optimizer (shard Adam states over DP)
    pub dist_opt: bool,
}

impl ParallelCfg {
    pub fn single() -> Self {
        ParallelCfg { dp: 1, sp: 1, pp: 1, tp: 1, ep: 1, dist_opt: false }
    }
}

/// Parameter counts split by how each tensor shards.
#[derive(Clone, Copy, Debug)]
pub struct ParamSplit {
    /// embedding + head (shards over TP only)
    pub embed: usize,
    /// per-layer mixer + norms (shards over TP, splits over PP)
    pub dense_per_layer: usize,
    /// per-layer expert tensors (shards over EP and TP, splits over PP)
    pub expert_per_layer: usize,
}

pub fn param_split(c: &ModelConfig) -> ParamSplit {
    let d = c.d_model;
    let dq = c.n_heads * c.d_head;
    // mixer: wq/wk/wv/wo (+ gates, roughly one extra d*dq) + norms
    let mixer = 4 * d * dq + d * dq + 4 * d;
    let experts = c.n_experts * 3 * d * c.d_ffn + d * c.n_experts;
    ParamSplit {
        embed: c.vocab * d,
        dense_per_layer: mixer,
        expert_per_layer: experts,
    }
}

/// Per-worker parameter bytes under a parallel config.
pub fn param_bytes(c: &ModelConfig, p: &ParallelCfg) -> usize {
    let s = param_split(c);
    let layers_here = c.n_layers.div_ceil(p.pp);
    let dense = s.dense_per_layer * layers_here / p.tp;
    let experts = s.expert_per_layer * layers_here / (p.tp * p.ep);
    // embedding lives on first/last PP stage; count it once per worker
    // that holds it (pessimistic: every stage counts it / pp).
    let embed = s.embed / p.tp;
    (embed + dense + experts) * ELT
}

/// Activation bytes per worker for one training step.
/// `flash`: attention layers avoid materializing the (N, N) score matrix
/// (FlashAttention-2 comparator); the standard Baseline does not.
pub fn activation_bytes(
    c: &ModelConfig,
    batch: usize,
    seq: usize,
    p: &ParallelCfg,
    flash: bool,
) -> usize {
    let b = batch.div_ceil(p.dp);
    let n = seq.div_ceil(p.sp);
    let d = c.d_model;
    let layers_here = c.n_layers.div_ceil(p.pp);
    let mut per_layer_tok = 0usize;
    // x, ln(x), q,k,v(+gate), o, moe hidden (top_k * d_ffn / d per token)
    per_layer_tok += (6 * d) / p.tp + 2 * d;
    per_layer_tok += c.top_k * c.d_ffn / p.tp;
    let mut bytes = b * n * per_layer_tok * layers_here * ELT;
    // quadratic score matrices on 'N' layers without flash
    let n_attn = c.layout.chars().filter(|&ch| ch == 'N').count();
    let attn_here = n_attn.div_ceil(p.pp);
    if attn_here > 0 && !flash {
        bytes += b * (c.n_heads / p.tp.min(c.n_heads)).max(1) * n * n * attn_here * ELT;
    }
    bytes
}

/// Optimizer + gradient bytes per worker.
pub fn optimizer_bytes(c: &ModelConfig, p: &ParallelCfg) -> usize {
    let params = param_bytes(c, p);
    let adam = if p.dist_opt { 2 * params / p.dp } else { 2 * params };
    params /* grads */ + adam
}

/// Total training-step memory per worker (Table 3 / Table 4 model).
pub fn train_bytes(
    c: &ModelConfig,
    batch: usize,
    seq: usize,
    p: &ParallelCfg,
    flash: bool,
) -> usize {
    param_bytes(c, p) + optimizer_bytes(c, p)
        + activation_bytes(c, batch, seq, p, flash)
}

/// Decode-time state bytes (Fig. 5 model): LSM layers carry constant
/// (Dk, Dv) states; attention layers carry KV caches of length `pos`.
pub fn decode_state_bytes(c: &ModelConfig, batch: usize, pos: usize) -> usize {
    let mut bytes = 0usize;
    for ch in c.layout.chars() {
        if ch == 'L' {
            bytes += batch * c.n_heads * c.d_head * c.d_head * ELT;
        } else {
            bytes += 2 * batch * c.n_heads * pos * c.d_head * ELT;
        }
    }
    bytes
}

/// Decode-time total (params + state).
pub fn decode_bytes(c: &ModelConfig, batch: usize, pos: usize) -> usize {
    param_bytes(c, &ParallelCfg::single()) + decode_state_bytes(c, batch, pos)
}

pub fn gib(bytes: usize) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

pub fn mib(bytes: usize) -> f64 {
    bytes as f64 / (1u64 << 20) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(layout: &str) -> ModelConfig {
        ModelConfig {
            vocab: 2048,
            d_model: 128,
            n_heads: 2,
            d_head: 64,
            n_layers: layout.len(),
            layout: layout.to_string(),
            lsm: "gla".into(),
            chunk: 64,
            n_experts: 4,
            top_k: 2,
            d_ffn: 128,
            capacity_factor: 2.0,
        }
    }

    #[test]
    fn lsm_training_memory_flat_in_seq() {
        // Table 3 claim: at fixed tokens/iter, LSM memory is ~flat while
        // Baseline (no flash) grows with N.
        let c = cfg("LLLL");
        let p = ParallelCfg::single();
        let m1 = train_bytes(&c, 8, 256, &p, false);
        let m2 = train_bytes(&c, 1, 2048, &p, false);
        let ratio = m2 as f64 / m1 as f64;
        assert!((0.8..1.2).contains(&ratio), "lsm ratio {ratio}");

        let ca = cfg("NNNN");
        let a1 = train_bytes(&ca, 8, 256, &p, false);
        let a2 = train_bytes(&ca, 1, 2048, &p, false);
        assert!(a2 as f64 / a1 as f64 > 1.5, "attn should grow: {a1} -> {a2}");
        // ...and flash flattens it (the FlashAttention-2 row)
        let f1 = train_bytes(&ca, 8, 256, &p, true);
        let f2 = train_bytes(&ca, 1, 2048, &p, true);
        assert!((f2 as f64 / f1 as f64) < 1.2);
    }

    #[test]
    fn decode_memory_constant_vs_growing() {
        // Fig. 5 claim.
        let cl = cfg("LLLL");
        let ca = cfg("NNNN");
        let l1 = decode_state_bytes(&cl, 16, 1024);
        let l2 = decode_state_bytes(&cl, 16, 131072);
        assert_eq!(l1, l2, "LSM decode state must be constant");
        let a1 = decode_state_bytes(&ca, 16, 1024);
        let a2 = decode_state_bytes(&ca, 16, 131072);
        assert_eq!(a2, a1 * 128, "KV cache linear in decode length");
    }

    #[test]
    fn parallelism_shards_memory() {
        // Table 4 (bottom) shape: EP=8 cuts expert params; TP=8 cuts all
        // matmul params; PP=8 cuts layers.
        let c = cfg("LLLLLLLL");
        let base = train_bytes(&c, 4, 2048, &ParallelCfg::single(), false);
        let ep8 = train_bytes(
            &c, 4, 2048,
            &ParallelCfg { dp: 1, sp: 1, pp: 1, tp: 1, ep: 8, dist_opt: false },
            false);
        let tp8 = train_bytes(
            &c, 4, 2048,
            &ParallelCfg { dp: 1, sp: 1, pp: 1, tp: 8, ep: 1, dist_opt: false },
            false);
        let pp8 = train_bytes(
            &c, 4, 2048,
            &ParallelCfg { dp: 1, sp: 1, pp: 8, tp: 1, ep: 1, dist_opt: false },
            false);
        assert!(ep8 < base);
        assert!(tp8 < ep8, "tp shards more than ep (tp8={tp8} ep8={ep8})");
        assert!(pp8 < base);
    }
}
