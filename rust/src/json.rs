//! Minimal JSON parser *and writer* for the artifact manifest, bench
//! output, and the tracing exporters.
//!
//! The build environment is offline (no serde); the parser covers the
//! JSON subset `aot.py` emits: objects, arrays, strings (with escapes),
//! numbers, booleans, null.  The writer is the mirror image: `Display`
//! emits compact single-line JSON, [`Json::pretty`] the 2-space-indented
//! form, and both round-trip through [`parse`] bit-exactly (objects are
//! `BTreeMap`s, so key order -- and therefore the emitted bytes -- is
//! deterministic).  Non-finite numbers have no JSON spelling and are
//! written as `null`.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shorthand: `v.str_field("name")?` for required string fields.
    pub fn str_field(&self, key: &str) -> Result<String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
    }

    /// Build an object from key/value pairs (keys sort; last wins on dup).
    pub fn obj(pairs: impl IntoIterator<Item = (String, Json)>) -> Json {
        Json::Obj(pairs.into_iter().collect())
    }

    /// Compact single-line serialization (same as `to_string()` via
    /// `Display`, kept as a method for call-site clarity).
    pub fn write(&self, out: &mut String) {
        use fmt::Write as _;
        let _ = write!(out, "{self}");
    }

    /// Pretty serialization: 2-space indent, one key per line -- the shape
    /// the hand-rolled bench emitters used to produce.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        use fmt::Write as _;
        let pad = |out: &mut String, d: usize| out.push_str(&"  ".repeat(d));
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    pad(out, depth + 1);
                    v.pretty_into(out, depth + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    pad(out, depth + 1);
                    let _ = write!(out, "{}: ", Json::Str(k.clone()));
                    v.pretty_into(out, depth + 1);
                    out.push_str(if i + 1 < map.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push('}');
            }
            other => {
                let _ = write!(out, "{other}");
            }
        }
    }
}

/// Write a number the parser reads back to the same `f64`.  Integral
/// values in the exactly-representable range drop the fraction (`5`, not
/// `5.0`); Rust's shortest-round-trip float formatting covers the rest.
/// JSON has no NaN/inf, so non-finite values degrade to `null`.
fn write_num(f: &mut fmt::Formatter<'_>, n: f64) -> fmt::Result {
    if !n.is_finite() {
        return write!(f, "null");
    }
    if n == n.trunc() && n.abs() < 9.0e15 {
        write!(f, "{}", n as i64)
    } else {
        write!(f, "{n}")
    }
}

fn write_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            '\u{8}' => write!(f, "\\b")?,
            '\u{c}' => write!(f, "\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write_num(f, *n),
            Json::Str(s) => write_str(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_str(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code)
                                .unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let mut j = self.i;
                    while j < self.b.len()
                        && self.b[j] != b'"'
                        && self.b[j] != b'\\'
                    {
                        j += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..j])?);
                    self.i = j;
                    let _ = c;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_json() {
        let s = r#"{"version": 1, "artifacts": [
            {"name": "a", "shape": [2, 128], "dtype": "float32",
             "nested": {"x": -1.5e3, "ok": true, "none": null}}
        ]}"#;
        let v = parse(s).unwrap();
        assert_eq!(v.usize_field("version").unwrap(), 1);
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.str_field("name").unwrap(), "a");
        let shape: Vec<usize> = a.get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 128]);
        assert_eq!(a.get("nested").unwrap().get("x").unwrap().as_f64(),
                   Some(-1500.0));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn writer_round_trips() {
        let v = Json::obj([
            ("name".to_string(), Json::from("a\nb\t\"c\"\\")),
            ("count".to_string(), Json::from(42u64)),
            ("ratio".to_string(), Json::from(0.1 + 0.2)),
            ("neg".to_string(), Json::from(-1.5e-3)),
            ("flag".to_string(), Json::from(true)),
            ("none".to_string(), Json::Null),
            (
                "items".to_string(),
                Json::Arr(vec![Json::from(1u64), Json::from("x"), Json::Bool(false)]),
            ),
        ]);
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v, "compact round-trip");
        let pretty = v.pretty();
        assert_eq!(parse(&pretty).unwrap(), v, "pretty round-trip");
        assert!(pretty.contains("  \"count\": 42"), "pretty indents: {pretty}");
    }

    #[test]
    fn writer_is_deterministic_and_escapes_controls() {
        let v = Json::obj([
            ("b".to_string(), Json::from("\u{1}")),
            ("a".to_string(), Json::from(5.0)),
        ]);
        // BTreeMap keys sort, integral floats drop the fraction
        assert_eq!(v.to_string(), "{\"a\":5,\"b\":\"\\u0001\"}");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back.get("b").unwrap().as_str(), Some("\u{1}"));
    }

    #[test]
    fn writer_maps_non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        // large magnitudes keep full precision through the round-trip
        let big = Json::Num(1.0e300);
        let back = parse(&big.to_string()).unwrap();
        assert_eq!(back.as_f64(), Some(1.0e300));
    }
}
