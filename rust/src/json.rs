//! Minimal JSON parser for the artifact manifest.
//!
//! The build environment is offline (no serde); this parser covers the
//! JSON subset `aot.py` emits: objects, arrays, strings (with escapes),
//! numbers, booleans, null.  ~200 lines, fully tested.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shorthand: `v.str_field("name")?` for required string fields.
    pub fn str_field(&self, key: &str) -> Result<String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
    }
}

pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code)
                                .unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let mut j = self.i;
                    while j < self.b.len()
                        && self.b[j] != b'"'
                        && self.b[j] != b'\\'
                    {
                        j += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..j])?);
                    self.i = j;
                    let _ = c;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_json() {
        let s = r#"{"version": 1, "artifacts": [
            {"name": "a", "shape": [2, 128], "dtype": "float32",
             "nested": {"x": -1.5e3, "ok": true, "none": null}}
        ]}"#;
        let v = parse(s).unwrap();
        assert_eq!(v.usize_field("version").unwrap(), 1);
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.str_field("name").unwrap(), "a");
        let shape: Vec<usize> = a.get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 128]);
        assert_eq!(a.get("nested").unwrap().get("x").unwrap().as_f64(),
                   Some(-1500.0));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }
}
