//! Host-side tensor type: the currency of the coordinator.
//!
//! Workers exchange `Tensor`s (plain host buffers) through collectives and
//! channels; the runtime converts them to/from `xla::Literal` at the PJRT
//! boundary.  Only the dtypes the artifacts use are supported (f32 / i32).

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::i32(&[], vec![v])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, Data::F32(_))
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match &mut self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Zero the buffer in place (keeps shape, dtype, and allocation).
    pub fn fill_zero(&mut self) {
        match &mut self.data {
            Data::F32(v) => v.fill(0.0),
            Data::I32(v) => v.fill(0),
        }
    }

    /// First element as f32 (for scalar results like losses).
    pub fn item_f32(&self) -> Result<f32> {
        self.as_f32()?.first().copied()
            .ok_or_else(|| anyhow!("empty tensor"))
    }

    /// Convert to an XLA literal for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => xla::Literal::vec1(v),
            Data::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(&dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported element type {other:?}"),
        }
    }

    /// Elementwise add (used for gradient accumulation across microbatches
    /// and for folding tied-embedding grads).
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        anyhow::ensure!(self.shape == other.shape, "shape mismatch");
        let b = other.as_f32()?.to_vec();
        let a = self.as_f32_mut()?;
        for (x, y) in a.iter_mut().zip(b) {
            *x += y;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) -> Result<()> {
        for x in self.as_f32_mut()? {
            *x *= s;
        }
        Ok(())
    }
}

/// A named, ordered bundle of tensors (a flattened pytree: model params,
/// optimizer state, gradients...).  Order always matches the manifest's
/// flatten order, which is what the HLO artifacts expect.
#[derive(Clone, Debug, Default)]
pub struct Bundle {
    pub tensors: Vec<Tensor>,
}

impl Bundle {
    pub fn new(tensors: Vec<Tensor>) -> Self {
        Bundle { tensors }
    }

    pub fn zeros_like(&self) -> Self {
        Bundle {
            tensors: self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    pub fn add_assign(&mut self, other: &Bundle) -> Result<()> {
        anyhow::ensure!(self.tensors.len() == other.tensors.len());
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            a.add_assign(b)?;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) -> Result<()> {
        for t in &mut self.tensors {
            t.scale(s)?;
        }
        Ok(())
    }

    /// Concatenate all f32 tensors into one flat vector (optimizer
    /// bucketing).  Returns (flat, per-tensor lengths).
    pub fn flatten_f32(&self) -> Result<(Vec<f32>, Vec<usize>)> {
        let mut flat = Vec::with_capacity(self.numel());
        let mut lens = Vec::with_capacity(self.tensors.len());
        for t in &self.tensors {
            let v = t.as_f32()?;
            flat.extend_from_slice(v);
            lens.push(v.len());
        }
        Ok((flat, lens))
    }

    /// Inverse of `flatten_f32`: write `flat` back into the bundle.
    pub fn unflatten_f32(&mut self, flat: &[f32]) -> Result<()> {
        let mut off = 0;
        for t in &mut self.tensors {
            let dst = t.as_f32_mut()?;
            dst.copy_from_slice(&flat[off..off + dst.len()]);
            off += dst.len();
        }
        anyhow::ensure!(off == flat.len(), "flat length mismatch");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_flatten() {
        let mut b = Bundle::new(vec![
            Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]),
            Tensor::f32(&[3], vec![5., 6., 7.]),
        ]);
        let (flat, lens) = b.flatten_f32().unwrap();
        assert_eq!(flat, vec![1., 2., 3., 4., 5., 6., 7.]);
        assert_eq!(lens, vec![4, 3]);
        let double: Vec<f32> = flat.iter().map(|x| x * 2.0).collect();
        b.unflatten_f32(&double).unwrap();
        assert_eq!(b.tensors[1].as_f32().unwrap(), &[10., 12., 14.]);
    }

    #[test]
    fn add_and_scale() {
        let mut a = Tensor::f32(&[2], vec![1., 2.]);
        let b = Tensor::f32(&[2], vec![3., 4.]);
        a.add_assign(&b).unwrap();
        a.scale(0.5).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[2., 3.]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let mut a = Tensor::f32(&[2], vec![1., 2.]);
        let b = Tensor::f32(&[3], vec![3., 4., 5.]);
        assert!(a.add_assign(&b).is_err());
    }
}
