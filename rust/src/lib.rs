//! Linear-MoE: a Rust + JAX + Pallas reproduction of
//! "Linear-MoE: Linear Sequence Modeling Meets Mixture-of-Experts" (2025).
//!
//! Three layers:
//!  - L1: Pallas LSM kernels (build-time Python, python/compile/kernels)
//!  - L2: JAX Linear-MoE model, AOT-lowered to HLO text (python/compile)
//!  - L3: this crate -- the Training/Inference subsystems: PJRT runtime,
//!        collectives, device mesh, LASP sequence parallelism, pipeline
//!        schedules, expert-parallel MoE dispatch, distributed optimizer,
//!        data pipeline, metrics, CLI.

pub mod json;
pub mod trace;
pub mod rng;
pub mod fault;
pub mod tensor;
pub mod runtime;
pub mod collectives;
pub mod topology;
pub mod memcost;
pub mod data;
pub mod coordinator;
pub mod inference;
pub mod serve;
pub mod eval;
pub mod bench_util;
